"""Scenario engine: populations, arrivals, events, determinism, parity,
the cohort fast path, and the EngineResult tail-window guard."""
import numpy as np
import pytest

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.core.safl import (
    EngineResult,
    scenario_dropout,
    scenario_resource_scale,
    scenario_unstable_resources,
)
from repro.data import make_federated_data
from repro.models import make_mlp_spec
from repro.scenarios import (
    AlwaysOn,
    BimodalSpeeds,
    BurstArrivals,
    Churn,
    CohortEngine,
    DiurnalArrivals,
    Dropout,
    LabelDrift,
    LognormalSpeeds,
    PoissonArrivals,
    Population,
    ResourceScale,
    Scenario,
    SpeedJitter,
    TraceReplay,
    UniformSpeeds,
    VirtualTaskData,
    ZipfSpeeds,
    get_scenario,
    list_scenarios,
)
from repro.serve import scenario_stream


@pytest.fixture(scope="module")
def rwd_data():
    return make_federated_data("rwd", 10, sigma=1.0, seed=0, n_total=1000)


@pytest.fixture(scope="module")
def spec():
    return make_mlp_spec()


def _run(data, spec, rounds=6, **kw):
    hp = FedQSHyperParams(buffer_k=4)
    eng = SAFLEngine(data, spec, make_algorithm("fedqs-sgd", hp), hp, seed=1, **kw)
    return eng, eng.run(rounds)


ARRIVALS = {
    "always-on": lambda: AlwaysOn(),
    "poisson": lambda: PoissonArrivals(mean_gap=5.0),
    "diurnal": lambda: DiurnalArrivals(mean_gap=5.0, period=100.0),
    "burst": lambda: BurstArrivals(quiet_gap=10.0),
}


class TestPopulations:
    @pytest.mark.parametrize("model", [
        UniformSpeeds(), LognormalSpeeds(), BimodalSpeeds(), ZipfSpeeds()])
    def test_speed_models_shape_and_determinism(self, model):
        a = model.sample(500, np.random.default_rng(7))
        b = model.sample(500, np.random.default_rng(7))
        assert a.shape == (500,)
        assert np.all(np.isfinite(a)) and np.all(a > 0)
        np.testing.assert_array_equal(a, b)

    def test_cohort_sampling(self):
        pop = Population(n_labels=10)
        c = pop.sample(200, np.random.default_rng(0))
        assert c.n == 200
        assert c.label_probs.shape == (200, 10)
        np.testing.assert_allclose(c.label_probs.sum(1), 1.0, atol=1e-5)
        assert c.n_samples.min() >= pop.quantity.min_samples

    def test_default_speeds_match_legacy_engine_draw(self):
        # Scenario without a population must consume the engine's historic
        # single uniform draw (the seeded-run reproducibility contract)
        rng1, rng2 = np.random.default_rng(3), np.random.default_rng(3)
        legacy = rng1.uniform(1.0, 50.0, 20)
        np.testing.assert_array_equal(Scenario().sample_speeds(20, rng2, 50.0), legacy)


class TestArrivalDeterminism:
    @pytest.mark.parametrize("name", sorted(ARRIVALS))
    def test_event_trace_deterministic(self, name):
        def trace(seed):
            arr = ARRIVALS[name]()
            rng = np.random.default_rng(seed)
            t = arr.start(8, rng)
            events = [tuple(t)]
            now = float(np.nanmax(t[np.isfinite(t)])) if np.isfinite(t).any() else 0.0
            for cid in range(8):
                for _ in range(5):
                    now2 = arr.next_start(cid, now, rng)
                    events.append((cid, now2))
                    if not np.isfinite(now2):
                        break
            return events

        assert trace(11) == trace(11)

    @pytest.mark.parametrize("name", sorted(ARRIVALS))
    def test_engine_metrics_deterministic(self, rwd_data, spec, name):
        def run():
            scn = Scenario(name=name, arrivals=ARRIVALS[name]())
            return _run(rwd_data, spec, rounds=4, scenario=scn)[1].metrics

        m1, m2 = run(), run()
        assert m1 == m2  # RoundMetrics dataclasses compare exactly
        assert len(m1) == 4


class TestDynamicsParity:
    """The dynamics-callback shim and the equivalent Scenario must produce
    bit-identical RoundMetrics (satellite requirement)."""

    CASES = [
        (lambda: scenario_resource_scale(3, 100.0), lambda: ResourceScale(3, 100.0)),
        (lambda: scenario_unstable_resources(), lambda: SpeedJitter()),
        (lambda: scenario_dropout(2, 0.5), lambda: Dropout(2, 0.5)),
    ]

    @pytest.mark.parametrize("case", range(len(CASES)))
    def test_callback_vs_scenario_bit_identical(self, rwd_data, spec, case):
        legacy_fn, event_fn = self.CASES[case]
        _, res_cb = _run(rwd_data, spec, rounds=6, dynamics=legacy_fn())
        _, res_shim = _run(rwd_data, spec, rounds=6,
                           scenario=Scenario.from_dynamics(legacy_fn()))
        _, res_event = _run(rwd_data, spec, rounds=6,
                            scenario=Scenario(events=(event_fn(),)))
        assert res_cb.metrics == res_shim.metrics
        assert res_cb.metrics == res_event.metrics

    def test_no_scenario_matches_static(self, rwd_data, spec):
        _, plain = _run(rwd_data, spec, rounds=5)
        _, static = _run(rwd_data, spec, rounds=5, scenario=get_scenario("static"))
        assert plain.metrics == static.metrics

    def test_both_dynamics_and_scenario_rejected(self, rwd_data, spec):
        hp = FedQSHyperParams(buffer_k=4)
        with pytest.raises(ValueError):
            SAFLEngine(rwd_data, spec, make_algorithm("fedqs-sgd", hp), hp,
                       dynamics=scenario_dropout(2, 0.5),
                       scenario=get_scenario("dropout"))

    def test_sync_mode_rejects_dynamic_scenarios(self, rwd_data, spec):
        hp = FedQSHyperParams(buffer_k=4)
        for scn in (get_scenario("dropout"), get_scenario("diurnal")):
            with pytest.raises(ValueError):
                SAFLEngine(rwd_data, spec, make_algorithm("fedqs-sgd", hp), hp,
                           scenario=scn, sync_mode=True)


class TestEvents:
    def test_churn_revives_clients(self, rwd_data, spec):
        eng, res = _run(rwd_data, spec, rounds=9,
                        scenario=Scenario(events=(Churn(period=2, frac=0.4),)))
        # churn cycles: the engine must still be serving and clients that
        # left must have been revived at the next churn tick
        assert len(res.metrics) == 9
        assert eng.alive.sum() >= rwd_data.n_clients // 2

    def test_revival_does_not_fork_event_chains(self, rwd_data, spec):
        # a client that dies and is revived before its stale heap event pops
        # must resume as ONE event chain: consecutive uploads from it must be
        # ~speed apart (a forked chain would halve the gaps)
        from repro.scenarios.events import DynamicEvent
        from repro.serve import CaptureStream

        class KillThenRevive(DynamicEvent):
            def apply(self, rnd, speeds, rng):
                out = speeds.copy()
                if rnd == 1:
                    out[0] = np.nan
                    return out
                if rnd == 2:
                    out[0] = 40.0
                    return out
                return None

        hp = FedQSHyperParams(buffer_k=4)
        eng = SAFLEngine(rwd_data, spec, make_algorithm("fedqs-sgd", hp), hp,
                         seed=1, scenario=Scenario(events=(KillThenRevive(),)))
        eng.speeds[0] = eng.clients[0].speed = 40.0  # slow: stale event lingers
        cap = CaptureStream()
        cap.wrap(eng.service)
        eng.run(12)
        times = [t for u, t in cap.updates if u.cid == 0]
        gaps = np.diff(times)
        assert len(gaps) == 0 or gaps.min() >= 0.9 * 40.0

    def test_label_drift_mutates_data(self):
        data = make_federated_data("rwd", 6, sigma=1.0, seed=3, n_total=600)
        before = [c.y.copy() for c in data.clients]
        spec_ = make_mlp_spec()
        _run(data, spec_, rounds=4,
             scenario=Scenario(events=(LabelDrift(at_round=1, frac=0.5),)))
        changed = sum(not np.array_equal(b, c.y)
                      for b, c in zip(before, data.clients))
        assert changed >= 1


class TestTraceReplay:
    def _trace(self, tmp_path):
        p = tmp_path / "trace.csv"
        rows = ["client_id,t_arrival,t_compute"]
        for cid in range(6):
            for k in range(8):
                rows.append(f"{cid},{k * 10.0 + cid},{2.0 + cid * 0.5}")
        p.write_text("\n".join(rows) + "\n")
        return str(p)

    def test_trace_drives_engine(self, rwd_data, spec, tmp_path):
        path = self._trace(tmp_path)
        data6 = make_federated_data("rwd", 6, sigma=1.0, seed=0, n_total=600)
        scn = get_scenario(f"trace:{path}")
        hp = FedQSHyperParams(buffer_k=3)
        eng = SAFLEngine(data6, spec, make_algorithm("fedqs-sgd", hp), hp,
                         seed=1, scenario=scn)
        res = eng.run(8)
        # 48 trace events / K=3 → at most 16 rounds; the run must end when
        # the trace is exhausted, never hang
        assert 1 <= eng.round <= 16
        # compute times are pinned by the trace: finish = arrival + t_compute,
        # so virtual time stays within the trace horizon + max compute
        assert res.virtual_time() <= 80.0 + 5.0

    def test_trace_determinism(self, tmp_path):
        path = self._trace(tmp_path)
        a = TraceReplay.from_file(path)
        b = TraceReplay.from_file(path)
        rng = np.random.default_rng(0)
        np.testing.assert_array_equal(a.start(6, rng), b.start(6, rng))
        assert a.next_start(0, 0.5, rng) == b.next_start(0, 0.5, rng)

    def test_exhausted_trace_returns_inf(self, tmp_path):
        tr = TraceReplay([(0, 1.0, 2.0)])
        rng = np.random.default_rng(0)
        assert tr.start(1, rng)[0] == 1.0
        assert tr.next_start(0, 5.0, rng) == float("inf")


class TestScenarioStream:
    def test_deterministic_and_sized(self, spec):
        import jax

        params = spec.init(jax.random.PRNGKey(0))

        def run():
            return [(u.cid, u.stale_round, t) for u, t in
                    scenario_stream(params, get_scenario("diurnal-churn"),
                                    32, 60, seed=4)]

        s1, s2 = run(), run()
        assert s1 == s2
        assert len(s1) == 60
        times = [t for _, _, t in s1]
        assert times == sorted(times)


class TestCohortEngine:
    def test_runs_and_deterministic(self):
        def run():
            eng = CohortEngine(get_scenario("diurnal-churn"), 300,
                               hp=FedQSHyperParams(buffer_k=16),
                               cohort_k=16, seed=5, eval_every=1)
            return eng, eng.run(5)

        e1, r1 = run()
        e2, r2 = run()
        assert r1.metrics == r2.metrics
        assert len(r1.metrics) == 5
        assert all(np.isfinite(m.loss) for m in r1.metrics)
        assert e1.service.stats.rounds == 5

    def test_staleness_emerges(self):
        eng = CohortEngine(get_scenario("diurnal"), 300,
                           hp=FedQSHyperParams(buffer_k=16),
                           cohort_k=16, seed=0, eval_every=1)
        res = eng.run(6)
        assert any(m.n_stale > 0 for m in res.metrics)

    def test_events_apply(self):
        scn = Scenario(events=(Dropout(at_round=2, frac=0.5),))
        eng = CohortEngine(scn, 200, hp=FedQSHyperParams(buffer_k=16),
                           cohort_k=16, seed=0)
        eng.run(4)
        assert (~eng.alive).sum() == 100

    def test_data_events_rejected(self):
        with pytest.raises(ValueError):
            CohortEngine(get_scenario("drift"), 100)

    def test_virtual_data_label_skew(self):
        task = VirtualTaskData.make(n_labels=4, n_features=6, seed=0)
        probs = np.zeros((3, 4), np.float32)
        probs[:, 1] = 1.0  # every client only holds label 1
        xs, ys = task.sample_cohort_batches(probs, 2, 16, np.random.default_rng(0))
        assert xs.shape == (3, 2, 16, 6)
        assert (ys == 1).all()


class TestCatalog:
    def test_all_names_construct(self):
        for name in list_scenarios():
            scn = get_scenario(name)
            assert scn.describe()

    def test_unknown_name(self):
        with pytest.raises(KeyError):
            get_scenario("nope")

    def test_overrides_forwarded(self):
        scn = get_scenario("dropout", at_round=7, frac=0.25)
        assert "@7" in scn.events[0].describe()

    def test_unknown_override_rejected(self):
        with pytest.raises(TypeError):
            get_scenario("churn", perod=5)  # typo must not be swallowed


class TestFinalAccuracyGuard:
    def _result(self, accs):
        from repro.core.types import RoundMetrics

        ms = [RoundMetrics(i, float(i), 0.0, a, 0, 0.0) for i, a in enumerate(accs)]
        return EngineResult(ms, 0.0, None)

    def test_tail_window_mean(self):
        res = self._result([0.1, 0.2, 0.9, 0.7])
        assert res.final_accuracy(2) == pytest.approx(0.8)
        assert res.final_accuracy(1) == pytest.approx(0.7)
        # window larger than history averages what exists
        assert res.final_accuracy(100) == pytest.approx(np.mean([0.1, 0.2, 0.9, 0.7]))

    @pytest.mark.parametrize("last", [0, -1, -20])
    def test_non_positive_window_rejected(self, last):
        with pytest.raises(ValueError):
            self._result([0.5]).final_accuracy(last)

    def test_empty_metrics(self):
        assert self._result([]).final_accuracy() == 0.0
