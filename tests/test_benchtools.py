"""Benchmark-artifact tooling: the BENCH_*.json schema validator and the
perf-regression detector (scripts/check_bench_schema.py,
scripts/bench_diff.py)."""
import copy
import importlib.util
import json
import os

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_ROOT, "scripts", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bench_diff = _load("bench_diff")
check_schema = _load("check_bench_schema")


def _artifact(**rows):
    return {
        "suite": "serve", "fast": True, "generated_unix": 1700000000,
        "wall_s": 1.5,
        "results": [
            {"name": name, "us_per_call": us,
             "derived": {"updates_per_sec": "100.0"}}
            for name, us in rows.items()
        ],
    }


class TestBenchDiff:
    def test_flags_synthetic_2x_regression(self):
        base = _artifact(a=100.0, b=50.0)
        cur = _artifact(a=210.0, b=55.0)  # a slowed 2.1x, b is noise
        diff = bench_diff.compare(base, cur, threshold=2.0)
        assert [r["name"] for r in diff["regressions"]] == ["a"]
        assert diff["regressions"][0]["ratio"] == pytest.approx(2.1)
        assert "REGRESSION" in bench_diff.format_diff(diff)

    def test_passes_identical_artifacts(self):
        base = _artifact(a=100.0, b=50.0)
        diff = bench_diff.compare(base, copy.deepcopy(base))
        assert diff["regressions"] == []
        assert all(r["ratio"] == pytest.approx(1.0) for r in diff["rows"])

    def test_zero_baseline_rows_are_skipped(self):
        # pass/fail marker rows record us_per_call 0.0; any current value
        # would be an infinite ratio, so they must never gate
        base = _artifact(parity=0.0, a=100.0)
        cur = _artifact(parity=0.0, a=100.0)
        diff = bench_diff.compare(base, cur, threshold=2.0)
        row = next(r for r in diff["rows"] if r["name"] == "parity")
        assert row["ratio"] is None and not row["regressed"]

    def test_added_and_removed_rows_reported_not_gated(self):
        base = _artifact(a=100.0, gone=10.0)
        cur = _artifact(a=100.0, fresh=10.0)
        diff = bench_diff.compare(base, cur)
        assert diff["added"] == ["fresh"]
        assert diff["removed"] == ["gone"]
        assert diff["regressions"] == []

    def test_cli_exit_codes(self, tmp_path, capsys):
        basep = tmp_path / "base.json"
        curp = tmp_path / "cur.json"
        basep.write_text(json.dumps(_artifact(a=100.0)))
        curp.write_text(json.dumps(_artifact(a=300.0)))
        assert bench_diff.main([str(curp), "--baseline", str(basep)]) == 1
        assert bench_diff.main([str(curp), "--baseline", str(basep),
                                "--report-only"]) == 0
        out = capsys.readouterr().out
        assert "not failing the build" in out
        # same artifact as its own baseline: clean pass
        assert bench_diff.main([str(curp), "--baseline", str(curp)]) == 0

    def test_cli_passes_on_committed_baseline(self, capsys):
        # the repo-root artifacts ARE the committed baselines — diffing
        # them against HEAD must be regression-free (acceptance gate)
        cwd = os.getcwd()
        os.chdir(_ROOT)
        try:
            rc = bench_diff.main(["BENCH_serve.json", "BENCH_ingest.json"])
        finally:
            os.chdir(cwd)
        assert rc == 0, capsys.readouterr().out


class TestCheckBenchSchema:
    def test_valid_artifact(self):
        assert check_schema.validate_payload(_artifact(a=1.0)) == []

    def test_committed_artifacts_validate(self):
        for name in ("BENCH_serve.json", "BENCH_ingest.json"):
            doc = json.load(open(os.path.join(_ROOT, name)))
            assert check_schema.validate_payload(doc, name) == []

    @pytest.mark.parametrize("mutate,fragment", [
        (lambda d: d.pop("suite"), "'suite'"),
        (lambda d: d.update(fast="yes"), "'fast'"),
        (lambda d: d.update(generated_unix=1.5), "'generated_unix'"),
        (lambda d: d.update(wall_s="1.5"), "'wall_s'"),
        (lambda d: d.update(results="nope"), "'results'"),
        (lambda d: d["results"][0].pop("name"), "'name'"),
        (lambda d: d["results"][0].update(us_per_call="12"),
         "'us_per_call'"),
        (lambda d: d["results"][0].update(derived={"rounds": 12}),
         "derived['rounds']"),
        (lambda d: d["results"].append(dict(d["results"][0])), "duplicate"),
    ])
    def test_violations_are_caught(self, mutate, fragment):
        doc = _artifact(a=1.0)
        mutate(doc)
        errors = check_schema.validate_payload(doc)
        assert errors, f"mutation not caught: {fragment}"
        assert any(fragment in e for e in errors), errors

    def test_cli(self, tmp_path, capsys, monkeypatch):
        good = tmp_path / "BENCH_ok.json"
        good.write_text(json.dumps(_artifact(a=1.0)))
        assert check_schema.main([str(good)]) == 0
        bad = tmp_path / "BENCH_bad.json"
        bad.write_text("{not json")
        assert check_schema.main([str(bad)]) == 1
        empty = tmp_path / "empty"
        empty.mkdir()
        monkeypatch.chdir(empty)
        assert check_schema.main([]) == 1  # no artifacts found
        capsys.readouterr()
