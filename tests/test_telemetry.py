"""Telemetry plane: events, metrics, sinks, runtime instrumentation,
and the experiment-report generator (docs/OBSERVABILITY.md)."""
import json

import jax
import numpy as np
import pytest

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_mlp_spec
from repro.serve import (
    KBuffer,
    StalenessAdmission,
    StreamingAggregator,
    replay,
    synthetic_stream,
)
from repro.telemetry import (
    EVENT_TYPES,
    JsonlSink,
    MetricsRegistry,
    RingSink,
    Telemetry,
    UpdateAdmitted,
)
from repro.telemetry.report import (
    experiment_report,
    gini,
    load_events,
    report_from_jsonl,
)


@pytest.fixture(scope="module")
def mlp_params():
    return make_mlp_spec().init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def stream(mlp_params):
    return list(synthetic_stream(mlp_params, 16, 60, seed=0))


def _service(mlp_params, telemetry=None, **kw):
    hp = FedQSHyperParams(buffer_k=5)
    return StreamingAggregator(
        make_algorithm("fedqs-sgd", hp), hp, mlp_params, 16,
        trigger=KBuffer(5), telemetry=telemetry, **kw)


class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("a.count", unit="updates", layer="serve")
        c.inc()
        c.inc(3)
        assert c.value == 4
        g = reg.gauge("a.level")
        g.set(7.5)
        assert g.value == 7.5
        h = reg.histogram("a.hist", (1, 2, 4), unit="rounds")
        for v in (0, 1, 3, 100):
            h.observe(v)
        assert h.count == 4
        assert h.counts == [2, 0, 1, 1]  # <=1, (1,2], (2,4], overflow
        assert h.mean == pytest.approx(26.0)
        assert (h.vmin, h.vmax) == (0, 100)

    def test_get_or_create_idempotent_and_typed(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_histogram_bounds_must_be_sorted(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.histogram("bad", (3, 1, 2))

    def test_snapshot_is_json_serializable(self):
        reg = MetricsRegistry()
        reg.counter("c", unit="u", layer="l").inc(2)
        reg.histogram("h", (1, 10)).observe(5)
        snap = json.loads(json.dumps(reg.snapshot()))
        assert snap["c"] == {"type": "counter", "unit": "u", "layer": "l",
                             "value": 2}
        assert snap["h"]["counts"] == [0, 1, 0]


class TestSinksAndHub:
    def test_ring_sink_bounded(self):
        ring = RingSink(capacity=3)
        for i in range(10):
            ring.write({"e": "x", "i": i})
        assert [r["i"] for r in ring.records] == [7, 8, 9]

    def test_ring_sink_counts_evictions(self):
        ring = RingSink(capacity=3)
        for i in range(10):
            ring.write({"e": "x", "i": i})
        assert ring.dropped == 7
        ring.clear()
        assert ring.dropped == 0

    def test_ring_evictions_surface_in_dropped_counter(self):
        tel = Telemetry.in_memory(capacity=4)
        for i in range(10):
            tel.emit(UpdateAdmitted(t=float(i), round=0, cid=i, n_samples=1,
                                    stale_round=0, staleness=0,
                                    downweighted=False))
        tel.close()
        # close() itself appends the snapshot record, evicting once more
        snap = next(r for r in reversed(tel.ring.records)
                    if r["e"] == "metrics-snapshot")
        assert snap["metrics"]["telemetry_events_dropped"]["value"] >= 6

    def test_jsonl_flush_on_close_under_concurrent_writers(self, tmp_path):
        import threading

        path = str(tmp_path / "concurrent.jsonl")
        sink = JsonlSink(path)
        n_threads, per_thread = 8, 200

        def writer(k):
            for i in range(per_thread):
                sink.write({"e": "x", "k": k, "i": i})

        threads = [threading.Thread(target=writer, args=(k,))
                   for k in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        sink.flush()
        sink.close()
        sink.flush()  # no-op after close, must not raise
        records = load_events(path)
        assert len(records) == n_threads * per_thread
        # every record survived as one intact line per write
        seen = {(r["k"], r["i"]) for r in records}
        assert len(seen) == n_threads * per_thread

    def test_jsonl_round_trip_and_snapshot_on_close(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        tel = Telemetry.to_jsonl(path, ring=True)
        tel.metrics.counter("serve.rounds").inc(2)
        tel.emit(UpdateAdmitted(t=1.0, round=0, cid=4, n_samples=10,
                                stale_round=0, staleness=0,
                                downweighted=False))
        tel.close(t=2.0)
        tel.close()  # idempotent
        records = load_events(path)
        assert [r["e"] for r in records] == ["update-admitted",
                                            "metrics-snapshot"]
        assert records[0]["cid"] == 4
        assert records[1]["metrics"]["serve.rounds"]["value"] == 2
        assert tel.ring is not None and len(tel.ring) == 2

    def test_load_events_rejects_malformed(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text('{"e": "x"}\nnot json\n')
        with pytest.raises(ValueError, match="malformed"):
            load_events(str(path))

    def test_event_records_match_taxonomy(self):
        # every typed event's record carries its registered name
        for name, cls in EVENT_TYPES.items():
            assert cls.name == name


class TestServiceInstrumentation:
    def test_admitted_and_round_fired_events(self, mlp_params, stream):
        tel = Telemetry.in_memory()
        svc = _service(mlp_params, telemetry=tel)
        replay(svc, stream, flush=False)
        admitted = list(tel.ring.events("update-admitted"))
        fired = list(tel.ring.events("round-fired"))
        assert len(admitted) == svc.stats.accepted == len(stream)
        assert len(fired) == svc.stats.rounds == len(stream) // 5
        # member-level round composition matches the admission stream
        members = [m for rec in fired for m in rec["members"]]
        assert [m[0] for m in members] == [rec["cid"] for rec in admitted]
        # metrics mirror the service stats
        snap = tel.metrics.snapshot()
        assert snap["serve.submitted"]["value"] == svc.stats.submitted
        assert snap["serve.rounds"]["value"] == svc.stats.rounds
        assert snap["serve.staleness"]["count"] == len(members)
        assert snap["serve.agg_seconds"]["count"] == svc.stats.rounds

    def test_rejection_events_carry_reason(self, mlp_params, stream):
        tel = Telemetry.in_memory()
        svc = _service(mlp_params, telemetry=tel,
                       admission=StalenessAdmission(tau_max=0, mode="drop"))
        replay(svc, stream, flush=False)
        rejected = list(tel.ring.events("update-rejected"))
        assert len(rejected) == svc.stats.dropped > 0
        assert all("stale" in rec["reason"] for rec in rejected)
        assert tel.metrics.get("serve.rejected").value == svc.stats.dropped

    def test_disabled_telemetry_is_bit_identical(self, mlp_params, stream):
        plain = _service(mlp_params)
        tele = _service(mlp_params, telemetry=Telemetry.in_memory())
        replay(plain, stream, flush=False)
        replay(tele, stream, flush=False)
        for a, b in zip(jax.tree_util.tree_leaves(plain.global_params),
                        jax.tree_util.tree_leaves(tele.global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_flat_and_hier_member_streams_identical(self, mlp_params, stream):
        from repro.hier import HierarchicalService, parse_topology

        hp = FedQSHyperParams(buffer_k=5)

        def member_events(factory):
            tel = Telemetry.in_memory()
            replay(factory(tel), stream, flush=False)
            return [{k: v for k, v in rec.items() if k != "agg_seconds"}
                    for rec in tel.ring.records
                    if rec["e"] in ("update-admitted", "round-fired")]

        flat = member_events(lambda tel: _service(mlp_params, telemetry=tel))
        topo = parse_topology("hier:4", 16)
        hier = member_events(lambda tel: HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, mlp_params, 16, topo,
            trigger=KBuffer(5), telemetry=tel))
        assert flat == hier

    def test_hier_emits_tier_merged(self, mlp_params, stream):
        from repro.hier import HierarchicalService, parse_topology

        hp = FedQSHyperParams(buffer_k=5)
        tel = Telemetry.in_memory()
        svc = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, mlp_params, 16,
            parse_topology("hier:8x2", 16), trigger=KBuffer(5),
            telemetry=tel)
        replay(svc, stream, flush=False)
        tiers = list(tel.ring.events("tier-merged"))
        assert {rec["tier"] for rec in tiers} == {"edge", "region"}
        edge_fires = sum(1 for rec in tiers if rec["tier"] == "edge")
        assert edge_fires == sum(e.fires for e in svc.edges)
        assert tel.metrics.get("hier.edge_fires").value == edge_fires


class TestEngineInstrumentation:
    @pytest.fixture(scope="class")
    def recorded_run(self):
        hp = FedQSHyperParams(buffer_k=4)
        data = make_federated_data("rwd", 10, sigma=1.0, seed=0, n_total=800)
        tel = Telemetry.in_memory()
        eng = SAFLEngine(data, make_mlp_spec(),
                         make_algorithm("fedqs-sgd", hp), hp, seed=1,
                         telemetry=tel, compress="int8")
        res = eng.run(4)
        tel.close()
        return eng, res, tel

    def test_engine_emits_full_taxonomy(self, recorded_run):
        eng, _, tel = recorded_run
        names = {rec["e"] for rec in tel.ring.records}
        assert {"update-admitted", "round-fired", "codec-encoded",
                "client-classified", "round-metrics",
                "metrics-snapshot"} <= names

    def test_round_metrics_match_engine_result(self, recorded_run):
        _, res, tel = recorded_run
        events = list(tel.ring.events("round-metrics"))
        assert [rec["accuracy"] for rec in events] == \
            [m.accuracy for m in res.metrics]
        assert [rec["round"] for rec in events] == \
            [m.round for m in res.metrics]

    def test_codec_events_match_compressor_stats(self, recorded_run):
        eng, _, tel = recorded_run
        events = list(tel.ring.events("codec-encoded"))
        assert len(events) == eng.compressor.stats.updates
        assert sum(rec["wire_bytes"] for rec in events) == \
            eng.compressor.stats.payload_bytes
        # the event carries the parsed, self-describing spec string
        assert all(rec["spec"] == eng.compressor.codec.spec for rec in events)
        assert all(rec["spec"].startswith("int8") for rec in events)

    def test_quadrant_gauges_cover_population(self, recorded_run):
        eng, _, tel = recorded_run
        total = sum(
            tel.metrics.get(f"engine.quadrant_{q}").value
            for q in ("fsbc", "fwbc", "swbc", "ssbc"))
        assert total == eng.data.n_clients

    def test_cohort_engine_records(self):
        from repro.scenarios import CohortEngine, get_scenario

        tel = Telemetry.in_memory()
        eng = CohortEngine(get_scenario("static"), 64,
                           hp=FedQSHyperParams(buffer_k=8), seed=0,
                           telemetry=tel)
        eng.run(3)
        names = {rec["e"] for rec in tel.ring.records}
        assert {"update-admitted", "round-fired", "client-classified",
                "round-metrics"} <= names
        fired = list(tel.ring.events("round-fired"))
        assert len(fired) == 3
        assert all(rec["n_updates"] == 8 for rec in fired)


class TestReportGenerator:
    def test_gini(self):
        assert gini([]) == 0.0
        assert gini([5, 5, 5, 5]) == pytest.approx(0.0)
        assert gini([0, 0, 0, 12]) == pytest.approx(0.75)

    def test_report_sections_from_service_run(self, mlp_params, stream):
        tel = Telemetry.in_memory()
        replay(_service(mlp_params, telemetry=tel), stream, flush=False)
        tel.close()
        report = experiment_report(tel.ring.records, title="unit run")
        assert report.startswith("# unit run")
        for section in ("## Run overview", "## Staleness distribution",
                        "## Participation fairness",
                        "## Per-tier throughput", "## Metrics snapshot"):
            assert section in report
        assert "`update-admitted` events | 60" in report

    def test_report_from_jsonl_and_cli(self, mlp_params, stream, tmp_path,
                                       capsys):
        path = str(tmp_path / "run.jsonl")
        tel = Telemetry.to_jsonl(path)
        replay(_service(mlp_params, telemetry=tel), stream, flush=False)
        tel.close()
        report = report_from_jsonl(path)
        assert "## Staleness distribution" in report

        from repro.launch.analysis import main as analysis_main

        out = str(tmp_path / "report.md")
        analysis_main(["--events", path, "--out", out, "--title", "cli run"])
        assert "report" in capsys.readouterr().out
        assert open(out).read().startswith("# cli run")

    def test_report_with_engine_curves(self):
        hp = FedQSHyperParams(buffer_k=4)
        data = make_federated_data("rwd", 8, sigma=1.0, seed=0, n_total=600)
        tel = Telemetry.in_memory()
        SAFLEngine(data, make_mlp_spec(), make_algorithm("fedqs-sgd", hp),
                   hp, seed=0, telemetry=tel).run(3)
        report = experiment_report(tel.ring.records)
        assert "## Accuracy / loss" in report
        assert "## Mod-2 quadrant mix" in report

    def test_empty_records_render(self):
        report = experiment_report([])
        assert report.startswith("# Experiment report")

    def test_empty_events_file(self, tmp_path):
        path = tmp_path / "empty.jsonl"
        path.write_text("")
        assert load_events(str(path)) == []
        report = report_from_jsonl(str(path))
        assert "## Run overview" in report

    def test_unknown_event_types_skipped(self, mlp_params, stream):
        # forward compat: a log written by a newer build with event types
        # this build doesn't know must still render, not crash
        tel = Telemetry.in_memory()
        replay(_service(mlp_params, telemetry=tel), stream, flush=False)
        tel.close()
        records = list(tel.ring.records)
        records.insert(0, {"e": "from-the-future", "t": 0.0, "payload": 1})
        records.append({"e": "also-unknown"})
        report = experiment_report(records)
        assert "## Staleness distribution" in report
        assert f"events recorded | {len(records)}" in report

    def test_critical_path_section_from_traced_run(self, mlp_params,
                                                   stream):
        tel = Telemetry.in_memory(trace=True)
        replay(_service(mlp_params, telemetry=tel), stream, flush=False)
        tel.close()
        report = experiment_report(tel.ring.records)
        assert "## Critical path (traced run)" in report
        for stage in ("host_stack", "kernel_dispatch", "finalize",
                      "buffer_residency"):
            assert stage in report
        assert "## Kernel profile" not in report  # no profiler activated
        # untraced runs must not grow the section
        tel2 = Telemetry.in_memory()
        replay(_service(mlp_params, telemetry=tel2), stream, flush=False)
        tel2.close()
        assert "## Critical path" not in experiment_report(tel2.ring.records)

    def test_dropped_events_warning(self, mlp_params, stream):
        tel = Telemetry.in_memory(trace=True, trace_capacity=8)
        replay(_service(mlp_params, telemetry=tel), stream, flush=False)
        tel.close()
        report = experiment_report(tel.ring.records)
        assert "Warning — lossy recording" in report
        # a lossless run carries no warning
        tel2 = Telemetry.in_memory(trace=True)
        replay(_service(mlp_params, telemetry=tel2), stream, flush=False)
        tel2.close()
        assert "lossy recording" not in experiment_report(tel2.ring.records)
