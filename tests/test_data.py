"""Data pipeline: partition laws + federated dataset invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (
    dirichlet_partition,
    lognormal_partition,
    make_federated_data,
    synth_adult,
    synth_cifar10,
    synth_shakespeare,
)


class TestSynthetics:
    def test_cifar_deterministic(self):
        x1, y1 = synth_cifar10(n=100, seed=7)
        x2, y2 = synth_cifar10(n=100, seed=7)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
        assert x1.shape == (100, 16, 16, 3)

    def test_cifar_learnable_structure(self):
        """Class templates must separate: same-class pairs closer than
        cross-class pairs on average."""
        x, y = synth_cifar10(n=400, seed=0)
        x = x.reshape(len(x), -1)
        c0, c1 = x[y == 0], x[y == 1]
        intra = np.linalg.norm(c0[:10] - c0[10:20], axis=1).mean()
        inter = np.linalg.norm(c0[:10] - c1[:10], axis=1).mean()
        assert inter > intra * 0.99

    def test_shakespeare_roles_distinct(self):
        data = synth_shakespeare(n_roles=3, chars_per_role=512, seed=0)
        assert set(data) == {0, 1, 2}
        x0, _ = data[0]
        x1, _ = data[1]
        assert not np.array_equal(x0[: len(x1)], x1[: len(x0)])

    def test_adult_group_correlation(self):
        x, y, g = synth_adult(n=4000, seed=0)
        # the sensitive attribute shifts covariate 0 (heterogeneity source)
        assert x[g == 1, 0].mean() > x[g == 0, 0].mean() + 0.3


class TestPartitioners:
    @given(st.sampled_from([0.1, 0.5, 1.0]), st.integers(4, 12))
    @settings(max_examples=6, deadline=None)
    def test_dirichlet_partition_covers_everything(self, alpha, n_clients):
        y = np.random.default_rng(0).integers(0, 10, 600)
        parts = dirichlet_partition(y, n_clients, alpha, seed=1)
        all_idx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(all_idx, np.arange(600))

    def test_dirichlet_skew_increases_as_alpha_drops(self):
        y = np.random.default_rng(0).integers(0, 10, 4000)

        def label_skew(alpha):
            parts = dirichlet_partition(y, 10, alpha, seed=3)
            stds = []
            for ix in parts:
                hist = np.bincount(y[ix], minlength=10) / max(len(ix), 1)
                stds.append(hist.std())
            return np.mean(stds)

        assert label_skew(0.1) > label_skew(10.0)

    def test_lognormal_sizes_positive(self):
        parts = lognormal_partition(1000, 10, sigma=1.0, seed=0)
        assert all(len(p) >= 8 for p in parts)


class TestFederatedData:
    @pytest.mark.parametrize("task", ["cv", "nlp", "rwd"])
    def test_build_and_shapes(self, task):
        fed = make_federated_data(task, 6, seed=0, n_total=600)
        assert fed.n_clients == 6
        for c in fed.clients:
            assert c.n > 0 and len(c.val_x) > 0
        assert len(fed.test_x) > 0

    def test_per_label_val_accuracy_nan_for_missing(self):
        fed = make_federated_data("cv", 8, alpha=0.1, seed=0, n_total=600)
        c = fed.clients[0]
        acc = c.per_label_val_accuracy(lambda x: np.zeros(len(x), np.int64), 10)
        # label 0 predicted everywhere: accuracy defined only where label present
        present = np.unique(c.val_y)
        for lab in range(10):
            if lab not in present:
                assert np.isnan(acc[lab])

    def test_batches_respect_epochs(self):
        fed = make_federated_data("rwd", 4, seed=0, n_total=400)
        batches = list(fed.clients[0].batches(16, epoch_seed=0, n_batches=3))
        assert len(batches) == 3
        assert batches[0]["x"].shape[0] <= 16
