"""Compressed update transport: codec round-trips, error feedback,
fused dequant_agg kernel parity, service integration, checkpointing
(docs/COMPRESSION.md)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import (
    Chain,
    ClientCompressor,
    CompressedUpdate,
    Encoded,
    Int8Codec,
    TopKCodec,
    compress_stream,
    compress_update,
    decode,
    parse_codec,
    quantizer_stage,
    ravel_flat,
    ravel_flat_batch,
)
from repro.core import FedQSHyperParams, make_algorithm
from repro.core.types import AggregationStrategy, Update
from repro.kernels.dequant_agg import dequant_agg
from repro.kernels.ref import dequant_agg_ref, weighted_agg_ref
from repro.models import make_mlp_spec
from repro.serve import (
    StreamingAggregator,
    compressed_weighted_sum,
    replay,
    stack_encoded,
    stack_trees,
    synthetic_stream,
    unravel_like,
)
from repro.serve.batched import fused_eligible

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------- codecs
class TestInt8Codec:
    @pytest.mark.parametrize("d,chunk", [(100, 32), (1000, 256), (256, 256),
                                         (5, 8), (513, 64)])
    def test_round_trip_within_scale(self, d, chunk):
        v = jax.random.normal(KEY, (d,))
        enc = Int8Codec(chunk=chunk).encode(v, jax.random.PRNGKey(1))
        dec = decode(enc)
        # per-chunk error bound: stochastic rounding is within one level
        err = np.abs(np.asarray(dec - v))
        scale = np.repeat(np.asarray(enc.scales), chunk)[:d]
        assert (err <= scale + 1e-7).all()
        assert dec.shape == (d,)
        assert enc.data.dtype == jnp.int8

    def test_deterministic_halves_bound(self):
        v = jax.random.normal(KEY, (512,))
        enc = Int8Codec(chunk=128, stochastic=False).encode(v)
        err = np.abs(np.asarray(decode(enc) - v))
        scale = np.repeat(np.asarray(enc.scales), 128)
        assert (err <= 0.5 * scale + 1e-7).all()

    def test_stochastic_rounding_is_unbiased(self):
        v = jnp.full((256,), 0.3)  # 0.3/scale lands between levels
        codec = Int8Codec(chunk=256)
        outs = [
            np.asarray(decode(codec.encode(v, jax.random.PRNGKey(i))))
            for i in range(200)
        ]
        assert np.mean(outs) == pytest.approx(0.3, abs=5e-4)

    def test_zero_vector(self):
        enc = Int8Codec(chunk=64).encode(jnp.zeros(100), jax.random.PRNGKey(0))
        np.testing.assert_array_equal(np.asarray(decode(enc)), np.zeros(100))

    def test_wire_bytes_shrink(self):
        v = jax.random.normal(KEY, (4096,))
        enc = Int8Codec(chunk=256).encode(v, jax.random.PRNGKey(1))
        assert enc.nbytes < 4 * 4096 / 3  # ~4x minus scale overhead


class TestTopKCodec:
    def test_keeps_largest_exactly(self):
        v = jax.random.normal(KEY, (300,))
        enc = TopKCodec(k=30).encode(v)
        dec = np.asarray(decode(enc))
        keep = np.argsort(-np.abs(np.asarray(v)))[:30]
        assert set(np.flatnonzero(dec)) == set(keep)
        np.testing.assert_allclose(dec[keep], np.asarray(v)[keep], rtol=1e-6)

    def test_ratio_resolves_k(self):
        assert TopKCodec(ratio=0.05).resolve_k(1000) == 50
        assert TopKCodec(ratio=0.001).resolve_k(100) == 1  # floor of 1
        assert TopKCodec(k=5000).resolve_k(100) == 100      # capped at d

    def test_int16_indices_small_models(self):
        enc = TopKCodec(k=8).encode(jax.random.normal(KEY, (1000,)))
        assert enc.indices.dtype == jnp.int16
        enc = TopKCodec(k=8).encode(jax.random.normal(KEY, (40000,)))
        assert enc.indices.dtype == jnp.int32

    def test_rejects_bad_args(self):
        with pytest.raises(ValueError):
            TopKCodec()
        with pytest.raises(ValueError):
            TopKCodec(ratio=0.1, k=3)
        with pytest.raises(ValueError):
            TopKCodec(ratio=1.5)


class TestChain:
    def test_topk_int8_round_trip(self):
        v = jax.random.normal(KEY, (1000,))
        codec = parse_codec("topk:0.1|int8:chunk=128")
        enc = codec.encode(v, jax.random.PRNGKey(1))
        dec = np.asarray(decode(enc))
        keep = np.asarray(enc.indices, np.int64)
        # kept coordinates within one quantization level, others exactly 0
        scale = np.asarray(enc.scales)[keep // 128]
        err = np.abs(dec[keep] - np.asarray(v)[keep])
        assert (err <= scale + 1e-7).all()
        mask = np.ones(1000, bool)
        mask[keep] = False
        assert (dec[mask] == 0).all()

    def test_scales_live_on_decoded_chunks(self):
        v = jax.random.normal(KEY, (1024,))
        enc = parse_codec("topk:0.05|int8:chunk=256").encode(v, KEY)
        assert enc.scales.shape == (4,)
        assert enc.data.dtype == jnp.int8 and enc.data.shape == enc.indices.shape

    def test_unsupported_chains_rejected(self):
        with pytest.raises(ValueError):
            Chain([Int8Codec(), TopKCodec(ratio=0.1)])  # wrong order
        with pytest.raises(ValueError):
            parse_codec("int8|int8")


class TestSpecGrammar:
    @pytest.mark.parametrize("spec,cls", [
        ("none", "Identity"), ("int8", "Int8Codec"), ("topk:0.5", "TopKCodec"),
        ("topk:k=10", "TopKCodec"), ("topk:0.1|int8", "Chain"),
        ("topk:0.1 | int8:chunk=64:det", "Chain"),
    ])
    def test_parses(self, spec, cls):
        assert type(parse_codec(spec)).__name__ == cls

    def test_options(self):
        c = parse_codec("int8:chunk=64:det")
        assert c.chunk == 64 and not c.stochastic
        assert parse_codec("topk:k=7").k == 7
        assert parse_codec("topk:ratio=0.2").ratio == 0.2
        assert parse_codec("topk:1.0").ratio == 1.0  # keep-all, not k=1
        assert parse_codec("topk:12").k == 12
        with pytest.raises(ValueError):
            parse_codec("topk:2.5")  # fractional count

    @pytest.mark.parametrize("bad", ["gzip", "topk", "int8:chunk=0",
                                     "topk:2|int8|none|topk:0.1"])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            parse_codec(bad)

    @pytest.mark.parametrize("spec", [
        "topk :0.05", " topk:0.05 ", "topk:0.05 | int8", "topk: 0.05|int8",
    ])
    def test_whitespace_tolerated(self, spec):
        assert parse_codec(spec).spec == parse_codec(
            spec.replace(" ", "")).spec

    def test_unknown_stage_error_lists_known_stages(self):
        with pytest.raises(ValueError, match="none, int8, topk"):
            parse_codec("gzip")
        with pytest.raises(ValueError, match="known stages"):
            parse_codec("topk:0.1|zstd")
        with pytest.raises(ValueError, match="known stages"):
            parse_codec("int8|")  # trailing separator → empty stage

    def test_quantizer_stage(self):
        assert isinstance(quantizer_stage(parse_codec("topk:0.1|int8")), Int8Codec)
        assert type(quantizer_stage(parse_codec("topk:0.1"))).__name__ == "Identity"


# property-style sweep kept hypothesis-free so the suite collects on bare
# environments (conftest skips any module importing hypothesis when absent)
@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("chunk", [16, 64, 256])
def test_property_int8_round_trip(seed, chunk):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(8, 400))
    v = jax.random.normal(jax.random.PRNGKey(d), (d,)) * 3.0
    enc = Int8Codec(chunk=chunk).encode(v, jax.random.PRNGKey(d + 1))
    err = np.abs(np.asarray(decode(enc) - v))
    bound = np.repeat(np.asarray(enc.scales), chunk)[:d] + 1e-7
    assert (err <= bound).all()


# ------------------------------------------------------- error feedback
class TestErrorFeedback:
    def test_cumulative_error_vanishes_on_fixed_stream(self):
        """Encoding the same delta round after round, the *average*
        transported value converges to the true delta — the residual
        re-injects dropped mass until every coordinate crosses."""
        v = jax.random.normal(KEY, (400,))
        comp = ClientCompressor("topk:0.1|int8", 2, seed=0)
        acc = np.zeros(400)
        errs = []
        for t in range(1, 161):
            acc += np.asarray(decode(comp.encode_delta(0, v)))
            errs.append(np.abs(acc / t - np.asarray(v)).max())
        assert errs[-1] < 0.1 * errs[4]   # decays ~1/T with rounds
        assert errs[-1] < 0.08            # and is small in absolute terms

    def test_residual_bounded(self):
        v = jax.random.normal(KEY, (400,))
        comp = ClientCompressor("topk:0.25|int8", 1, seed=0)
        norms = []
        for _ in range(60):
            comp.encode_delta(0, v)
            norms.append(np.linalg.norm(comp.residual[0]))
        assert max(norms[30:]) <= max(norms[:30]) + 1e-3  # no blow-up

    def test_no_feedback_keeps_no_state(self):
        comp = ClientCompressor("topk:0.1", 4, error_feedback=False)
        comp.encode_delta(0, jnp.ones(64))
        assert comp.residual is None

    def test_batch_matches_sequential(self):
        flats = jax.random.normal(KEY, (4, 256))
        a = ClientCompressor("topk:0.25|int8:det", 4, seed=0)
        encs = a.encode_flat_batch(np.arange(4), flats)
        b = ClientCompressor("topk:0.25|int8:det", 4, seed=0)
        # deterministic quantization: batch and sequential encodes agree
        for i in range(4):
            e = b.encode_delta(i, flats[i])
            np.testing.assert_array_equal(np.asarray(encs[i].data),
                                          np.asarray(e.data))
            np.testing.assert_allclose(np.asarray(encs[i].scales),
                                       np.asarray(e.scales), rtol=1e-6)
        np.testing.assert_allclose(a.residual, b.residual, atol=1e-6)

    def test_dimension_change_rejected(self):
        comp = ClientCompressor("int8", 2)
        comp.encode_delta(0, jnp.ones(64))
        with pytest.raises(ValueError):
            comp.encode_delta(1, jnp.ones(65))


# ------------------------------------------------------- fused kernel
class TestDequantAgg:
    @pytest.mark.parametrize("K,D,chunk", [
        (2, 256, 64), (4, 1024, 256), (10, 4096, 256), (3, 512, 512),
        (16, 12288, 128), (5, 8192, 4096), (8, 640, 128),
    ])
    def test_matches_oracle(self, K, D, chunk):
        q = jax.random.randint(KEY, (K, D), -127, 128, jnp.int8)
        s = jax.random.uniform(jax.random.PRNGKey(1), (K, D // chunk)) * 0.01
        w = jax.random.uniform(jax.random.PRNGKey(2), (K,))
        got = dequant_agg(q, s, w, chunk=chunk, interpret=True)
        np.testing.assert_allclose(got, dequant_agg_ref(q, s, w),
                                   rtol=1e-5, atol=1e-6)

    def test_matches_decode_then_weighted_agg(self):
        K, D, chunk = 6, 2048, 256
        q = jax.random.randint(KEY, (K, D), -127, 128, jnp.int8)
        s = jax.random.uniform(jax.random.PRNGKey(1), (K, D // chunk)) * 0.01
        w = jax.random.uniform(jax.random.PRNGKey(2), (K,))
        dense = (q.astype(jnp.float32).reshape(K, D // chunk, chunk)
                 * s[..., None]).reshape(K, D)
        got = dequant_agg(q, s, w, chunk=chunk, interpret=True)
        np.testing.assert_allclose(got, weighted_agg_ref(dense, w),
                                   rtol=1e-5, atol=1e-6)

    def test_rejects_bad_shapes(self):
        q = jnp.zeros((2, 100), jnp.int8)
        with pytest.raises(ValueError):
            dequant_agg(q, jnp.zeros((2, 1)), jnp.ones(2), chunk=64,
                        interpret=True)

    def test_compressed_weighted_sum_matches_decode_path(self):
        d = 700
        vs = [jax.random.normal(jax.random.PRNGKey(i), (d,)) for i in range(5)]
        codec = parse_codec("topk:0.3|int8:chunk=128")
        encs = [codec.encode(v, jax.random.PRNGKey(10 + i))
                for i, v in enumerate(vs)]
        assert fused_eligible(encs)
        w = jnp.asarray([0.1, 0.2, 0.3, 0.25, 0.15])
        got = compressed_weighted_sum(encs, w, lambda f: f, use_kernel=True)
        want = weighted_agg_ref(jnp.stack([decode(e) for e in encs]), w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_stack_encoded_scatters_sparse(self):
        codec = parse_codec("topk:k=3|int8:chunk=64")
        v = jnp.zeros(128).at[jnp.asarray([5, 70, 100])].set(
            jnp.asarray([1.0, -2.0, 3.0]))
        enc = codec.encode(v, KEY)
        q, s = stack_encoded([enc, enc])
        assert q.shape == (2, 128) and s.shape == (2, 2)
        assert int((q[0] != 0).sum()) == 3

    def test_raw_topk_buffers_fall_back(self):
        encs = [parse_codec("topk:0.5").encode(
            jax.random.normal(jax.random.PRNGKey(i), (64,))) for i in range(3)]
        assert not fused_eligible(encs)
        w = jnp.ones(3) / 3
        got = compressed_weighted_sum(encs, w, lambda f: f, use_kernel=False)
        want = weighted_agg_ref(jnp.stack([decode(e) for e in encs]), w)
        np.testing.assert_allclose(got, want, rtol=1e-6)


class TestCompressedWeightedSumEdgeCases:
    """Boundary behavior of ``serve/batched.py::compressed_weighted_sum``:
    empty buffers, single-update buffers, and buffers that must take the
    documented decode fallback instead of the fused kernel."""

    def test_empty_buffer_raises(self):
        assert not fused_eligible([])
        with pytest.raises(ValueError, match="empty"):
            compressed_weighted_sum([], jnp.zeros(0), lambda f: f)

    def test_single_quantized_update(self):
        v = jax.random.normal(KEY, (300,))
        enc = parse_codec("int8:chunk=64").encode(v, KEY)
        assert fused_eligible([enc])
        got = compressed_weighted_sum([enc], jnp.asarray([2.0]), lambda f: f,
                                      use_kernel=False)
        np.testing.assert_allclose(got, 2.0 * decode(enc), rtol=1e-6)

    def test_single_raw_update_takes_decode_path(self):
        v = jax.random.normal(KEY, (128,))
        enc = parse_codec("topk:0.25").encode(v)
        assert not fused_eligible([enc])
        got = compressed_weighted_sum([enc], jnp.asarray([1.0]), lambda f: f,
                                      use_kernel=False)
        np.testing.assert_allclose(got, decode(enc), rtol=1e-6)

    def test_heterogeneous_wire_formats_decode(self):
        # int8 + raw top-k in one buffer: not fused-eligible, but the
        # decode fallback still aggregates them correctly together
        v0 = jax.random.normal(KEY, (256,))
        v1 = jax.random.normal(jax.random.PRNGKey(1), (256,))
        encs = [parse_codec("int8:chunk=64").encode(v0, KEY),
                parse_codec("topk:0.5").encode(v1)]
        assert not fused_eligible(encs)
        w = jnp.asarray([0.4, 0.6])
        got = compressed_weighted_sum(encs, w, lambda f: f, use_kernel=False)
        want = weighted_agg_ref(jnp.stack([decode(e) for e in encs]), w)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_chunk_mismatch_not_fused(self):
        v = jax.random.normal(KEY, (256,))
        encs = [parse_codec("int8:chunk=64").encode(v, KEY),
                parse_codec("int8:chunk=128").encode(v, KEY)]
        assert not fused_eligible(encs)
        w = jnp.asarray([0.5, 0.5])
        got = compressed_weighted_sum(encs, w, lambda f: f, use_kernel=False)
        want = weighted_agg_ref(jnp.stack([decode(e) for e in encs]), w)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_mixed_compressed_dense_service_buffer_densifies(self):
        """A stream mixing wire formats must trigger the documented
        decode fallback in the batched service — and produce the same
        global model as the equivalent all-dense buffer."""
        hp = FedQSHyperParams(buffer_k=4)
        spec = make_mlp_spec()
        params = spec.init(jax.random.PRNGKey(0))
        unravel = unravel_like(params)
        base = [u for u, _ in synthetic_stream(params, 8, 4, seed=3)]
        codec = parse_codec("int8")
        mixed = [
            compress_update(u, codec, jax.random.PRNGKey(i),
                            payloads=("delta",))
            if i % 2 == 0 else u
            for i, u in enumerate(base)
        ]
        # the dense twin decodes the compressed halves exactly
        dense = [u.to_update(unravel) if isinstance(u, CompressedUpdate)
                 else u for u in mixed]

        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                  params, 8, batched=True)
        densify_sizes = []
        orig = svc._densify
        svc._densify = lambda batch: (densify_sizes.append(len(batch)),
                                      orig(batch))[1]
        for i, u in enumerate(mixed):
            svc.submit(u, now=float(i))
        assert densify_sizes == [4], "mixed buffer must take the decode fallback"

        ref = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                  params, 8, batched=True)
        for i, u in enumerate(dense):
            ref.submit(u, now=float(i))
        for a, b in zip(jax.tree_util.tree_leaves(svc.global_params),
                        jax.tree_util.tree_leaves(ref.global_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)


# ------------------------------------------------------- stack_trees
class TestStackTrees:
    def test_unravel_closure_is_cached(self):
        t = {"a": jnp.ones((3, 4)), "b": jnp.zeros(5)}
        _, u1 = stack_trees([t, t])
        _, u2 = stack_trees([t])
        assert u1 is u2
        assert unravel_like(t) is u1

    def test_round_trips(self):
        t = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": jnp.ones(4)}
        x, unravel = stack_trees([t, t])
        back = unravel(x[0])
        np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(t["a"]))

    def test_mixed_structure_raises(self):
        with pytest.raises(ValueError):
            stack_trees([{"a": jnp.ones(3)}, {"b": jnp.ones(3)}])

    def test_f32_rows_not_recast(self):
        t = {"a": jnp.ones(8, jnp.float32)}
        x, _ = stack_trees([t])
        assert x.dtype == jnp.float32
        xb, _ = stack_trees([{"a": jnp.ones(8, jnp.bfloat16)}])
        assert xb.dtype == jnp.float32


# ------------------------------------------------------- wire update
def _mk_update(cid=0, stale=0, tree=None):
    tree = tree if tree is not None else {"w": jnp.ones((4, 4)), "b": jnp.zeros(4)}
    return Update(cid=cid, n_samples=10, stale_round=stale, lr=0.1,
                  similarity=0.5, feedback=False, speed_f=0.1,
                  delta=tree, params=tree)


class TestCompressedUpdate:
    def test_metadata_preserved_and_payload_encoded(self):
        cu = compress_update(_mk_update(cid=3, stale=2),
                             parse_codec("int8:chunk=16"), KEY)
        assert cu.cid == 3 and cu.stale_round == 2
        assert isinstance(cu.delta, Encoded) and isinstance(cu.params, Encoded)
        assert cu.nbytes < 2 * 4 * 20  # beats the 2x20-leaf fp32 payload

    def test_to_update_round_trips_structure(self):
        tree = {"w": jax.random.normal(KEY, (4, 4)), "b": jnp.zeros(4)}
        cu = compress_update(_mk_update(tree=tree), parse_codec("int8:chunk=16"), KEY)
        u = cu.to_update(unravel_like(tree))
        assert u.delta["w"].shape == (4, 4)
        np.testing.assert_allclose(np.asarray(u.delta["w"]),
                                   np.asarray(tree["w"]), atol=0.05)

    def test_ravel_flat_batch_matches_per_row(self):
        tree = {"w": jax.random.normal(KEY, (3, 2, 2)), "b": jnp.ones((3, 5))}
        flats = ravel_flat_batch(tree)
        row1 = ravel_flat(jax.tree_util.tree_map(lambda l: l[1], tree))
        np.testing.assert_array_equal(np.asarray(flats[1]), np.asarray(row1))


# ------------------------------------------------- service integration
class TestServiceIntegration:
    def _run(self, spec_str, batched, n=24, updates=100):
        hp = FedQSHyperParams(buffer_k=5)
        spec = make_mlp_spec()
        params = spec.init(jax.random.PRNGKey(0))
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                                  n, batched=batched)
        comp = ClientCompressor(spec_str, n, seed=0)
        svc.compressor = comp
        stream = compress_stream(
            iter(list(synthetic_stream(params, n, updates, seed=0))), comp,
            strategy=AggregationStrategy.GRADIENT)
        reports = replay(svc, stream)
        return svc, comp, reports

    @pytest.mark.parametrize("spec_str,batched", [
        ("int8", True), ("topk:0.2|int8", True), ("topk:0.2", True),
        ("int8", False), ("topk:0.2|int8", False),
    ])
    def test_rounds_fire_and_model_moves(self, spec_str, batched):
        svc, comp, reports = self._run(spec_str, batched)
        assert svc.stats.rounds >= 10 and len(reports) >= 10
        assert comp.stats.updates == 100
        moved = any(
            float(jnp.abs(l).max()) > 0
            for l in jax.tree_util.tree_leaves(svc.global_params))
        assert moved

    def test_int8_tracks_dense_aggregation(self):
        hp = FedQSHyperParams(buffer_k=5)
        spec = make_mlp_spec()
        params = spec.init(jax.random.PRNGKey(0))
        n = 24
        base = list(synthetic_stream(params, n, 100, seed=0))
        dense = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                    params, n, batched=True)
        replay(dense, iter(base))
        comp = ClientCompressor("int8:chunk=64", n, seed=0)
        compressed = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                         params, n, batched=True)
        replay(compressed, compress_stream(iter(base), comp,
                                           strategy=AggregationStrategy.GRADIENT))
        gap = max(
            float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(jax.tree_util.tree_leaves(dense.global_params),
                            jax.tree_util.tree_leaves(compressed.global_params)))
        assert compressed.round == dense.round
        assert gap < 1e-3  # int8 deltas at 1e-3 scale: quantization-level gap

    def test_admission_drops_on_metadata_without_decoding(self):
        from repro.serve import StalenessAdmission

        hp = FedQSHyperParams(buffer_k=3)
        spec = make_mlp_spec()
        params = spec.init(jax.random.PRNGKey(0))
        svc = StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, 8,
            admission=StalenessAdmission(tau_max=0, mode="drop"), batched=True)
        svc.round = 5
        cu = compress_update(_mk_update(stale=1), parse_codec("int8"), KEY)
        cu.delta = None  # decoding this update would crash — admission must not
        cu.params = None
        res = svc.submit(cu, now=0.0)
        assert not res.accepted and svc.stats.dropped == 1

    def test_mixed_wire_formats_in_one_buffer(self):
        hp = FedQSHyperParams(buffer_k=2)
        tree = {"w": jax.random.normal(KEY, (6,))}
        svc = StreamingAggregator(make_algorithm("fedavg", hp), hp, tree, 4,
                                  batched=True)
        svc.submit(_mk_update(cid=0, tree={"w": jnp.ones(6)}), now=0.0)
        cu = compress_update(_mk_update(cid=1, tree={"w": jnp.full(6, 2.0)}),
                             parse_codec("int8"), KEY)
        res = svc.submit(cu, now=1.0)
        assert res.fired and svc.round == 1

    def test_stateful_algorithm_gets_decoded_trees(self):
        hp = FedQSHyperParams(buffer_k=3)
        spec = make_mlp_spec()
        params = spec.init(jax.random.PRNGKey(0))
        svc = StreamingAggregator(make_algorithm("fedbuff", hp), hp, params,
                                  12, batched=True)
        comp = ClientCompressor("int8", 12, seed=0)
        stream = compress_stream(
            iter(list(synthetic_stream(params, 12, 30, seed=0))), comp)
        reports = replay(svc, stream)
        assert svc.stats.rounds >= 8 and reports


# ------------------------------------------------- engines + checkpoint
class TestEngineCheckpoint:
    def test_cohort_compressed_runs_and_accounts_bytes(self):
        from repro.scenarios import CohortEngine, Scenario

        eng = CohortEngine(Scenario(), 64, hp=FedQSHyperParams(buffer_k=8),
                           cohort_k=8, seed=0, compress="topk:0.25|int8")
        res = eng.run(4)
        assert eng.round == 4
        s = eng.compressor.stats
        assert s.updates == 32 and s.ratio > 3.0
        assert res.metrics

    def test_service_checkpoint_round_trips_residuals(self):
        hp = FedQSHyperParams(buffer_k=4)
        spec = make_mlp_spec()
        params = spec.init(jax.random.PRNGKey(0))
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                                  8, batched=True)
        comp = ClientCompressor("topk:0.2|int8", 8, seed=0)
        svc.compressor = comp
        replay(svc, compress_stream(
            iter(list(synthetic_stream(params, 8, 24, seed=0))), comp,
            strategy=AggregationStrategy.GRADIENT))
        assert comp.residual is not None
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "ck")
            svc.save(path)
            assert os.path.exists(os.path.join(path, "codec.npz"))
            svc2 = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                       params, 8, batched=True)
            comp2 = ClientCompressor("topk:0.2|int8", 8, seed=0)
            svc2.compressor = comp2
            svc2.restore(path)
            np.testing.assert_array_equal(comp2.residual, comp.residual)
            assert svc2.round == svc.round

    def test_checkpoint_rejects_codec_mismatch(self):
        comp = ClientCompressor("int8", 4)
        with pytest.raises(ValueError):
            comp.load_state_dict({"spec": "topk:0.1", "residual": None})
