"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
variant of each assigned family, run one forward/train step + one decode
step on CPU, assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_reduced, supports_shape
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.frontend != "none":
        batch["memory_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = get_reduced(request.param)
    params = T.init_params(cfg, KEY)
    return request.param, cfg, params


class TestSmoke:
    def test_train_step_finite(self, arch):
        aid, cfg, params = arch
        batch = _batch(cfg)
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p, b: T.train_loss(cfg, p, b)))(params, batch)
        assert np.isfinite(float(loss)), f"{aid}: loss NaN"
        for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
            assert np.isfinite(np.asarray(g)).all(), f"{aid}: NaN grad at {path}"

    def test_forward_shapes(self, arch):
        aid, cfg, params = arch
        batch = _batch(cfg)
        h, aux = jax.jit(lambda p: T.forward(cfg, p, batch["tokens"],
                                             batch.get("memory_embeds")))(params)
        assert h.shape == (B, S, cfg.d_model)
        assert np.isfinite(np.asarray(h, dtype=np.float32)).all()

    def test_prefill_then_decode(self, arch):
        aid, cfg, params = arch
        batch = _batch(cfg)
        me = batch.get("memory_embeds")
        logits, cache = jax.jit(lambda p, t: T.prefill(cfg, p, t, me, max_seq=S + 8))(
            params, batch["tokens"])
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), f"{aid}: prefill NaN"
        lg, cache2 = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t, me))(
            params, cache, batch["tokens"][:, 0])
        assert lg.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(lg)).all(), f"{aid}: decode NaN"
        assert int(cache2["pos"]) == S + 1

    def test_reduced_respects_limits(self, arch):
        """Reduced variants must honor the smoke limits (≤2-ish layers per
        scan, d_model ≤ 512, ≤ 4 experts)."""
        aid, cfg, params = arch
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4
        assert cfg.n_layers <= 4


class TestDecodeConsistency:
    """Decode must continue prefill coherently: prefilling t tokens then
    decoding token t must equal prefilling t+1 tokens (same last logits)."""

    @pytest.mark.parametrize("aid", ["phi4-mini-3.8b", "gemma3-1b", "rwkv6-3b",
                                     "jamba-v0.1-52b", "deepseek-v3-671b"])
    def test_prefill_decode_agreement(self, aid):
        import dataclasses
        # capacity_factor→8 removes MoE token dropping, which otherwise
        # differs legitimately between a 9-token prefill and a 1-token
        # decode (different per-expert capacities) and masks the check.
        cfg = dataclasses.replace(get_reduced(aid), capacity_factor=8.0)
        params = T.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (1, 9), 0, cfg.vocab)
        # path A: prefill 8, decode token #8
        _, cache = T.prefill(cfg, params, toks[:, :8], max_seq=12)
        lgA, _ = T.decode_step(cfg, params, cache, toks[:, 8])
        # path B: prefill all 9 — last-position logits
        lgB, _ = T.prefill(cfg, params, toks, max_seq=12)
        np.testing.assert_allclose(np.asarray(lgA), np.asarray(lgB),
                                   rtol=5e-2, atol=5e-2)


class TestFullConfigs:
    def test_full_configs_match_assignment_table(self):
        spec = {
            "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163840, 384, 8),
            "seamless-m4t-medium": (12, 1024, 16, 16, 256206, 0, 0),
            "phi4-mini-3.8b": (32, 3072, 24, 8, 200064, 0, 0),
            "deepseek-v3-671b": (61, 7168, 128, 128, 129280, 256, 8),
            "minicpm-2b": (40, 2304, 36, 36, 122753, 0, 0),
            "jamba-v0.1-52b": (32, 4096, 32, 8, 65536, 16, 2),
            "rwkv6-3b": (32, 2560, 40, 40, 65536, 0, 0),
            "llama-3.2-vision-90b": (100, 8192, 64, 8, 128256, 0, 0),
            "gemma3-1b": (26, 1152, 4, 1, 262144, 0, 0),
            "qwen1.5-110b": (80, 8192, 64, 8, 152064, 0, 0),
        }
        for aid, (L, d, h, kv, v, e, k) in spec.items():
            cfg = get_config(aid)
            assert cfg.n_layers == L, f"{aid} layers {cfg.n_layers}!={L}"
            assert cfg.d_model == d
            assert cfg.n_heads == h
            assert cfg.n_kv_heads == kv
            assert cfg.vocab == v
            assert cfg.n_experts == e
            assert cfg.top_k == k

    def test_qwen_has_qkv_bias(self):
        assert get_config("qwen1.5-110b").qkv_bias

    def test_long500k_eligibility(self):
        ok = {a for a in ARCH_IDS if supports_shape(a, "long_500k")}
        assert ok == {"rwkv6-3b", "jamba-v0.1-52b", "gemma3-1b"}
        for a in ARCH_IDS:
            assert supports_shape(a, "train_4k")
            assert supports_shape(a, "decode_32k")

    def test_param_counts_plausible(self):
        # analytic counts should land near the advertised sizes
        assert 0.8e12 < get_config("kimi-k2-1t-a32b").param_count() < 1.3e12
        assert 0.55e12 < get_config("deepseek-v3-671b").param_count() < 0.8e12
        assert 2e9 < get_config("minicpm-2b").param_count() < 3.5e9
        assert 0.9e9 < get_config("gemma3-1b").param_count() < 2e9
        assert 90e9 < get_config("qwen1.5-110b").param_count() < 130e9
        # MoE active ≪ total
        kimi = get_config("kimi-k2-1t-a32b")
        assert kimi.active_param_count() < 0.1 * kimi.param_count()
