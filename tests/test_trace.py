"""Distributed tracing plane: span ring, Chrome-trace export, the
critical-path analyzer, and the kernel-profiling hooks
(docs/OBSERVABILITY.md)."""
import json

import jax
import numpy as np
import pytest

from repro.core import FedQSHyperParams, make_algorithm
from repro.models import make_mlp_spec
from repro.serve import (
    KBuffer,
    StreamingAggregator,
    TimeWindow,
    replay,
    synthetic_stream,
)
from repro.telemetry import Span, SpanRing, Telemetry, Tracer, to_chrome_trace
from repro.telemetry.critical_path import (
    OUT_OF_ROUND_STAGES,
    STAGES,
    analyze,
    format_summary,
    stage_summary,
)


@pytest.fixture(scope="module")
def mlp_params():
    return make_mlp_spec().init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def stream(mlp_params):
    return list(synthetic_stream(mlp_params, 16, 60, seed=0))


def _service(mlp_params, telemetry=None, *, trigger=None, **kw):
    hp = FedQSHyperParams(buffer_k=5)
    return StreamingAggregator(
        make_algorithm("fedqs-sgd", hp), hp, mlp_params, 16,
        trigger=trigger or KBuffer(5), telemetry=telemetry, **kw)


def _traced_replay(mlp_params, stream, **kw):
    tel = Telemetry.in_memory(trace=True)
    svc = _service(mlp_params, telemetry=tel, **kw)
    replay(svc, stream, flush=False)
    return svc, tel


class TestSpanRing:
    def test_bounded_drops_newest(self):
        ring = SpanRing(capacity=3)
        for i in range(5):
            ring.append(Span(f"s{i}", "serve", float(i), 0.1))
        assert len(ring) == 3
        assert [s.name for s in ring.spans] == ["s0", "s1", "s2"]
        assert ring.dropped == 2

    def test_clear_resets(self):
        ring = SpanRing(capacity=1)
        ring.append(Span("a", "serve", 0.0, 0.1))
        ring.append(Span("b", "serve", 0.0, 0.1))
        ring.clear()
        assert len(ring) == 0 and ring.dropped == 0

    def test_tracer_ids_and_span_context(self):
        tr = Tracer()
        assert [tr.new_trace() for _ in range(3)] == [0, 1, 2]
        with tr.span("work", "serve", round=2, tid=1):
            pass
        tr.record("admit", "update", tr.clock(), 0.01, tid=7)
        spans = tr.spans
        assert [s.name for s in spans] == ["work", "admit"]
        assert spans[0].round == 2 and spans[0].dur >= 0
        assert spans[1].tid == 7
        assert tr.dropped == 0


class TestChromeExport:
    def test_export_shape(self):
        spans = [Span("round", "serve", 1.0, 0.002, round=3),
                 Span("admit", "update", 0.5, 0.0001, tid=11),
                 Span("weighted_agg", "kernel", 1.0, 0.001,
                      args={"mode": "ref"})]
        doc = to_chrome_trace(spans)
        evs = doc["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        xs = [e for e in evs if e["ph"] == "X"]
        assert {e["args"]["name"] for e in meta} >= {"serve", "kernel",
                                                     "update"}
        assert len(xs) == 3
        by_name = {e["name"]: e for e in xs}
        # microsecond conversion and per-category lanes
        assert by_name["round"]["ts"] == pytest.approx(1e6)
        assert by_name["round"]["dur"] == pytest.approx(2000.0)
        assert by_name["round"]["args"]["round"] == 3
        assert by_name["admit"]["args"]["trace_id"] == 11
        assert by_name["weighted_agg"]["args"]["mode"] == "ref"
        assert by_name["round"]["tid"] != by_name["admit"]["tid"]
        # the whole document must be JSON-serializable as-is
        json.loads(json.dumps(doc))
        assert "metadata" not in doc

    def test_dropped_metadata(self):
        doc = to_chrome_trace([], dropped=4)
        assert doc["metadata"]["spans_dropped"] == 4


class TestCriticalPath:
    def test_synthetic_attribution(self):
        # dispatch covers stack+table; kernel is the derived remainder,
        # other the wall residual outside dispatch+finalize
        spans = [
            Span("stack", "serve", 0.0, 0.010, round=1),
            Span("table", "serve", 0.010, 0.005, round=1),
            Span("dispatch", "serve", 0.0, 0.040, round=1),
            Span("finalize", "serve", 0.040, 0.008, round=1),
            Span("round", "serve", 0.0, 0.050, round=1),
        ]
        (path,) = analyze(spans)
        assert path.round == 1
        assert path.stages["host_stack"] == pytest.approx(0.010)
        assert path.stages["table_update"] == pytest.approx(0.005)
        assert path.stages["kernel_dispatch"] == pytest.approx(0.025)
        assert path.stages["finalize"] == pytest.approx(0.008)
        assert path.stages["other"] == pytest.approx(0.002)
        assert path.coverage == pytest.approx(1.0)  # stages sum to wall
        summary = stage_summary(spans)
        # measured coverage excludes the residual
        assert summary["coverage"] == pytest.approx(0.048 / 0.050)
        assert set(summary["stages_s"]) == set(STAGES)
        assert set(summary["outside_s"]) == set(OUT_OF_ROUND_STAGES)

    def test_out_of_round_stages(self):
        spans = [
            Span("round", "serve", 0.0, 0.010, round=1),
            Span("dispatch", "serve", 0.0, 0.009, round=1),
            Span("admit", "update", 0.0, 0.001, tid=0),
            Span("buffer", "update", 0.0, 0.004, round=1, tid=0),
            Span("tier-fire", "hier", 0.0, 0.002),
            Span("save", "ckpt", 0.0, 0.003),
        ]
        s = stage_summary(spans)
        assert s["outside_s"]["admission_wait"] == pytest.approx(0.001)
        assert s["outside_s"]["buffer_residency"] == pytest.approx(0.004)
        assert s["outside_s"]["tier_merge"] == pytest.approx(0.002)
        assert s["outside_s"]["checkpoint"] == pytest.approx(0.003)
        assert s["outside_n"]["buffer_residency"] == 1
        # out-of-round stages never count toward coverage
        assert s["coverage"] == pytest.approx(0.9)
        rows = "\n".join(format_summary(s))
        assert "admission_wait" in rows and "kernel_dispatch" in rows

    def test_kbuffer_coverage_and_lineage(self, mlp_params, stream):
        svc, tel = _traced_replay(mlp_params, stream)
        spans = tel.tracer.spans
        s = stage_summary(spans)
        assert s["rounds"] == svc.stats.rounds == 12
        assert 0.9 <= s["coverage"] <= 1.1
        # per-update lineage: one admit span per submit, distinct trace
        # ids, one buffer-residency span per aggregated update
        admits = [sp for sp in spans if sp.name == "admit"]
        buffers = [sp for sp in spans if sp.name == "buffer"]
        assert len(admits) == svc.stats.submitted
        assert len({sp.tid for sp in admits}) == svc.stats.submitted
        assert len(buffers) == svc.stats.rounds * 5
        # every buffered update's residency is attributed to the round
        # that consumed it (1-based, matching RoundFired.round)
        assert {sp.round for sp in buffers} == set(range(1, 13))

    def test_timewindow_coverage(self, mlp_params, stream):
        svc, tel = _traced_replay(
            mlp_params, stream, trigger=TimeWindow(3.0, min_updates=2),
            batched=True)
        assert svc.stats.rounds > 0
        s = stage_summary(tel.tracer.spans)
        assert s["rounds"] == svc.stats.rounds
        assert 0.9 <= s["coverage"] <= 1.1
        # the batched fused path stamps host stack/table sub-stages
        assert s["stages_s"]["host_stack"] > 0
        assert s["stages_s"]["table_update"] > 0

    def test_hier_coverage_and_tier_spans(self, mlp_params, stream):
        from repro.hier import HierarchicalService, parse_topology

        hp = FedQSHyperParams(buffer_k=5)
        tel = Telemetry.in_memory(trace=True)
        svc = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, mlp_params, 16,
            parse_topology("hier:8x2", 16), trigger=KBuffer(5),
            telemetry=tel)
        replay(svc, stream, flush=False)
        spans = tel.tracer.spans
        s = stage_summary(spans)
        assert s["rounds"] == svc.stats.rounds > 0
        assert 0.9 <= s["coverage"] <= 1.1
        fires = [sp for sp in spans if sp.name == "tier-fire"]
        assert len(fires) == sum(e.fires for e in svc.edges) + \
            sum(r.fires for r in svc.regions)
        assert {sp.args["tier"] for sp in fires} == {"edge", "region"}
        assert s["outside_s"]["tier_merge"] > 0
        assert s["outside_s"]["buffer_residency"] > 0

    def test_tracing_is_bit_identical(self, mlp_params, stream):
        plain = _service(mlp_params)
        traced, _ = _traced_replay(mlp_params, stream)
        replay(plain, stream, flush=False)
        for a, b in zip(jax.tree_util.tree_leaves(plain.global_params),
                        jax.tree_util.tree_leaves(traced.global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_checkpoint_span(self, mlp_params, stream, tmp_path):
        svc, tel = _traced_replay(mlp_params, stream)
        svc.save(str(tmp_path / "svc.ckpt"))
        saves = [sp for sp in tel.tracer.spans if sp.cat == "ckpt"]
        assert len(saves) == 1 and saves[0].name == "save"


class TestProfileHooks:
    def test_resolved_mode(self, monkeypatch):
        from repro.telemetry import profile

        monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
        assert profile.resolved_mode() == "ref"
        monkeypatch.delenv("REPRO_KERNEL_MODE")
        if jax.default_backend() != "tpu":
            assert profile.resolved_mode(auto=True) == "ref"
            assert profile.resolved_mode(auto=False) == "interpret"

    def test_timed_call_passthrough_when_inactive(self):
        from repro.telemetry import profile

        assert profile.active() is None
        out = profile.timed_call("f", "ref", lambda x: x + 1, 2)
        assert out == 3

    def test_activation_times_kernel_dispatches(self):
        from repro.kernels import weighted_agg_auto_op
        from repro.telemetry import profile

        tel = Telemetry.in_memory(trace=True)
        x = jax.numpy.ones((4, 128), jax.numpy.float32)
        w = jax.numpy.ones((4,), jax.numpy.float32)
        with profile.activate(tel):
            assert profile.active() is not None
            weighted_agg_auto_op(x, w)
        assert profile.active() is None
        h = tel.metrics.get("kernels.dispatch_seconds")
        assert h.count >= 1
        kspans = [s for s in tel.tracer.spans if s.cat == "kernel"]
        assert len(kspans) == h.count
        assert kspans[0].name == "weighted_agg_auto_op"
        assert kspans[0].args["mode"] in ("ref", "pallas", "interpret")
        # closing the scope emitted the kernel-profile visibility record
        profs = list(tel.ring.events("kernel-profile"))
        assert len(profs) == 1
        assert profs[0]["dispatches"] == h.count
        assert profs[0]["backend"] == jax.default_backend()

    def test_autotune_probe_counters(self):
        from repro.kernels.autotune import get_config
        from repro.telemetry import profile

        tel = Telemetry.in_memory()
        with profile.activate(tel):
            get_config("ingest_agg", (8, 2048), jax.numpy.float32)
            get_config("ingest_agg", (3, 7), jax.numpy.float32)
        hits = tel.metrics.get("kernels.autotune_hits").value
        misses = tel.metrics.get("kernels.autotune_misses").value
        assert hits + misses == 2

    def test_nested_activation_restores_previous(self):
        from repro.telemetry import profile

        t1, t2 = Telemetry.in_memory(), Telemetry.in_memory()
        with profile.activate(t1):
            outer = profile.active()
            with profile.activate(t2):
                assert profile.active() is not outer
            assert profile.active() is outer
        assert profile.active() is None


class TestHubIntegration:
    def test_close_emits_trace_summary(self, mlp_params, stream):
        svc, tel = _traced_replay(mlp_params, stream)
        tel.close()
        recs = tel.ring.records
        assert [r["e"] for r in recs[-2:]] == ["trace-summary",
                                               "metrics-snapshot"]
        ts = recs[-2]
        assert ts["rounds"] == svc.stats.rounds
        assert ts["spans"] == len(tel.tracer.spans)
        assert 0.9 <= ts["coverage"] <= 1.1
        assert ts["spans_dropped"] == 0

    def test_span_drops_surface_in_counter(self, mlp_params, stream):
        tel = Telemetry.in_memory(trace=True, trace_capacity=8)
        svc = _service(mlp_params, telemetry=tel)
        replay(svc, stream, flush=False)
        assert tel.tracer.dropped > 0
        tel.close()
        snap = tel.metrics.snapshot()
        assert snap["telemetry_events_dropped"]["value"] == \
            tel.tracer.dropped

    def test_export_trace(self, mlp_params, stream, tmp_path, capsys):
        from repro.launch.analysis import export_trace

        _, tel = _traced_replay(mlp_params, stream)
        path = str(tmp_path / "run.trace.json")
        summary = export_trace(tel, path)
        assert "trace →" in capsys.readouterr().out
        assert 0.9 <= summary["coverage"] <= 1.1
        doc = json.load(open(path))
        assert doc["traceEvents"]
        assert export_trace.__module__  # importable symbol, not a stub

    def test_export_trace_requires_tracer(self, tmp_path):
        from repro.launch.analysis import export_trace

        with pytest.raises(ValueError, match="no tracer"):
            export_trace(Telemetry.in_memory(), str(tmp_path / "x.json"))

    def test_untraced_hub_has_no_summary(self):
        tel = Telemetry.in_memory()
        assert tel.tracer is None
        assert tel.trace_summary() is None
