"""Autotuner determinism and crash-safety (docs/KERNELS.md cache contract):

* same inputs → same chosen config, within a process and across fresh
  processes reading the same cache file;
* corrupt or missing cache → defaults with a warning, never an exception;
* kernel results are bit-identical whichever block size wins;
* the cache write is atomic (no torn file, no leftover tmp).
"""
import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (
    DEFAULT_BLOCKS,
    KernelConfig,
    autotune as run_autotune,
    cache_key,
    default_cache_path,
    get_config,
    load_cache,
    reload_cache,
    save_cache,
    shape_bucket,
)
from repro.kernels.ingest_agg import ingest_agg
from repro.kernels.weighted_agg import weighted_agg


def fake_timer(costs):
    """Deterministic cost model: µs per block_d, no measurement noise."""
    def timer(fn, repeats):
        block = fn()
        return costs[block]
    return timer


def make_call_stub(block_d):
    return lambda: block_d  # the "kernel" just reports its block


class TestCacheContract:
    def test_missing_cache_is_silent_default(self, tmp_path):
        path = str(tmp_path / "nope.json")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert load_cache(path) == {}
        cfg = get_config("ingest_agg", (8, 4096), jnp.float32, path=path)
        assert cfg.block_d == DEFAULT_BLOCKS["ingest_agg"]
        assert cfg.source == "default"

    @pytest.mark.parametrize("garbage", [
        "{not json", "[1, 2, 3]", "\x00\x01binary", ""])
    def test_corrupt_cache_warns_never_raises(self, tmp_path, garbage):
        path = str(tmp_path / "cache.json")
        with open(path, "w") as fh:
            fh.write(garbage)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            assert load_cache(path) == {}
        assert any(issubclass(w.category, RuntimeWarning) for w in caught)
        reload_cache(path)
        cfg = get_config("weighted_agg", (10, 1 << 20), jnp.float32, path=path)
        assert cfg.block_d == DEFAULT_BLOCKS["weighted_agg"]

    def test_entry_with_bad_block_degrades_to_default(self, tmp_path):
        path = str(tmp_path / "cache.json")
        key = cache_key("ingest_agg", (8, 4096), jnp.float32, backend="cpu")
        save_cache({key: {"block_d": "huge"}}, path)
        reload_cache(path)
        cfg = get_config("ingest_agg", (8, 4096), jnp.float32,
                         backend="cpu", path=path)
        assert cfg.block_d == DEFAULT_BLOCKS["ingest_agg"]

    def test_atomic_write_leaves_no_tmp(self, tmp_path):
        path = str(tmp_path / "sub" / "cache.json")
        save_cache({"k": {"block_d": 512}}, path)
        assert json.load(open(path)) == {"k": {"block_d": 512}}
        assert [f for f in os.listdir(os.path.dirname(path))
                if ".tmp" in f] == []

    def test_env_override_selects_path(self, tmp_path, monkeypatch):
        path = str(tmp_path / "env.json")
        monkeypatch.setenv(autotune.ENV_CACHE, path)
        assert default_cache_path() == path

    def test_shape_bucketing_shares_entries(self):
        assert shape_bucket((300,)) == shape_bucket((303,)) == (512,)
        key_a = cache_key("ingest_agg", (9, 300), jnp.float32, backend="cpu")
        key_b = cache_key("ingest_agg", (16, 303), jnp.float32, backend="cpu")
        assert key_a == key_b  # K 9→16, D 300/303→512


class TestDeterminism:
    COSTS = {512: 9.0, 1024: 3.0, 2048: 3.0, 4096: 7.0}

    def _tune(self, path):
        return run_autotune(
            "ingest_agg", make_call_stub, (8, 4096), jnp.float32,
            candidates=tuple(self.COSTS), timer=fake_timer(self.COSTS),
            bytes_moved=8 * 4096 * 4, backend="cpu", path=path)

    def test_tie_breaks_toward_smaller_block(self, tmp_path):
        cfg = self._tune(str(tmp_path / "c.json"))
        assert cfg.block_d == 1024  # 1024 and 2048 tie at 3.0 µs
        assert cfg.source == "measured"

    def test_repeat_run_hits_cache_verbatim(self, tmp_path):
        path = str(tmp_path / "c.json")
        first = self._tune(path)
        again = self._tune(path)
        assert again.source == "cache"
        assert again.block_d == first.block_d
        assert again.us == pytest.approx(first.us, rel=1e-6)

    def test_fresh_process_reads_same_config(self, tmp_path):
        """Cross-process determinism: a brand-new interpreter consulting
        the same cache file lands on the identical block."""
        path = str(tmp_path / "c.json")
        mine = self._tune(path)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        out = subprocess.run(
            [sys.executable, "-c",
             "import jax.numpy as jnp\n"
             "from repro.kernels.autotune import get_config\n"
             f"cfg = get_config('ingest_agg', (8, 4096), jnp.float32, "
             f"backend='cpu', path={path!r})\n"
             "print(cfg.block_d, cfg.source)"],
            env={**os.environ, "PYTHONPATH": src},
            capture_output=True, text=True, check=True)
        block, source = out.stdout.split()
        assert int(block) == mine.block_d
        assert source == "cache"

    def test_failed_candidate_is_skipped_with_warning(self, tmp_path):
        def timer(fn, repeats):
            block = fn()
            if block == 512:
                raise RuntimeError("vmem overflow")
            return float(block)
        with pytest.warns(RuntimeWarning, match="block_d=512 failed"):
            cfg = run_autotune(
                "ingest_agg", make_call_stub, (8, 4096), jnp.float32,
                candidates=(512, 1024), timer=timer, backend="cpu",
                path=str(tmp_path / "c.json"))
        assert cfg.block_d == 1024

    def test_all_candidates_failing_degrades_to_default(self, tmp_path):
        def timer(fn, repeats):
            raise RuntimeError("no")
        with pytest.warns(RuntimeWarning):
            cfg = run_autotune(
                "ingest_agg", make_call_stub, (8, 4096), jnp.float32,
                candidates=(512,), timer=timer, backend="cpu",
                path=str(tmp_path / "c.json"))
        assert cfg.block_d == DEFAULT_BLOCKS["ingest_agg"]
        assert cfg.source == "default"


class TestBlockSizeInvariance:
    """Results are bit-identical whichever config wins: block size only
    partitions the output axis."""

    def test_ingest_agg_bitwise_across_blocks(self):
        rng = np.random.default_rng(0)
        K, D = 6, 1000
        x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        n = jnp.asarray(rng.integers(1, 50, K).astype(np.float32))
        F = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
        G = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
        fb = jnp.asarray((rng.random(K) < 0.5).astype(np.float32))
        outs = [
            np.asarray(ingest_agg(x, None, n, F, G, fb, n_clients=32,
                                  block_d=b, interpret=True))
            for b in (128, 512, 4096)
        ]
        assert all((o == outs[0]).all() for o in outs[1:])

    def test_weighted_agg_bitwise_across_blocks(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((5, 700)).astype(np.float32))
        w = jnp.asarray(rng.uniform(0, 1, 5).astype(np.float32))
        outs = [np.asarray(weighted_agg(x, w, block_d=b, interpret=True))
                for b in (128, 1024)]
        assert (outs[0] == outs[1]).all()


class TestRooflineRows:
    def test_rows_from_cache(self, tmp_path):
        path = str(tmp_path / "c.json")
        save_cache({
            "ingest_agg|k8xd4096|float32|cpu": {
                "kernel": "ingest_agg", "block_d": 1024, "us": 10.0,
                "gbps": 100.0},
            "no_gbps|k1xd1|float32|cpu": {"block_d": 512},
        }, path)
        rows = autotune.roofline_rows(path, hbm_bw=1e12)
        assert len(rows) == 1
        assert rows[0]["kernel"] == "ingest_agg"
        assert rows[0]["pct_roofline"] == pytest.approx(10.0)
