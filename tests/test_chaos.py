"""Deterministic fault-injection harness (docs/ROBUSTNESS.md, "Chaos
testing"): seeded chaos schedules — mid-round battery death, duplicate and
out-of-order deliveries, checkpoint/restore with half-full buffers, edge
death between fires — driven through the serving plane.  Every schedule is
a pure function of its seed, so each test both exercises the failure mode
and doubles as a replay-determinism pin."""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedQSHyperParams, make_algorithm
from repro.models import make_mlp_spec
from repro.scenarios import DeviceStateModel, get_scenario
from repro.scenarios.scenario import Scenario
from repro.serve import (
    AdaptiveTimeWindow,
    KBuffer,
    StalenessAdmission,
    StreamingAggregator,
    replay,
    scenario_stream,
    synthetic_stream,
)
from repro.telemetry import Telemetry

KEY = jax.random.PRNGKey(0)


def _leaves_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _chaos_stream(params, n=24, updates=48, seed=7, telemetry=None):
    sc = Scenario(name="chaos", device=DeviceStateModel(
        drop_prob=0.15, partial_prob=0.4, partial_range=(0.2, 0.8)))
    return list(scenario_stream(params, sc, n, updates, seed=seed,
                                telemetry=telemetry))


# ---------------------------------------------------------------------------
# (a) a seeded chaos schedule through the adaptive service: terminates,
#     fires, and every admitted update is aggregated exactly once
# ---------------------------------------------------------------------------
class TestSeededChaosStream:
    SEED = 123

    def _run(self, seed):
        hp = FedQSHyperParams(buffer_k=8)
        params = make_mlp_spec().init(KEY)
        tel = Telemetry.in_memory()
        stream = list(scenario_stream(params, get_scenario("flaky-battery"),
                                      64, 160, seed=seed, telemetry=tel))
        svc = StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, 64,
            trigger=AdaptiveTimeWindow(window=3.0, min_updates=2),
            admission=StalenessAdmission(3), batched=True, telemetry=tel)
        reports = replay(svc, iter(stream))
        return svc, reports, tel, stream

    def test_terminates_and_counts_balance(self):
        svc, reports, tel, stream = self._run(self.SEED)
        s = svc.stats
        assert len(stream) == 160, "drops must not consume update slots"
        assert s.submitted == 160
        assert s.rounds == len(reports) > 0
        assert s.accepted == s.submitted - s.dropped
        assert svc.pending == 0  # replay() flushes: nothing may linger
        # per-cid ledger: occurrences across fires == admitted occurrences
        agg = Counter(int(m.cid) for rep in reports for m in rep.buffer)
        admitted = Counter(int(r["cid"])
                           for r in tel.ring.events("update-admitted"))
        assert agg == admitted
        # the chaos actually happened
        kinds = Counter(r["e"] for r in tel.ring.records)
        assert kinds["client-dropped"] > 0
        assert kinds["partial-admitted"] > 0


# ---------------------------------------------------------------------------
# (b) duplicate + out-of-order deliveries: the service counts occurrences,
#     never identities, and a count trigger cannot deadlock on a bad clock
# ---------------------------------------------------------------------------
class TestDuplicateAndOutOfOrder:
    def test_duplicates_counted_per_occurrence(self):
        hp = FedQSHyperParams(buffer_k=4)
        params = make_mlp_spec().init(KEY)
        base = list(synthetic_stream(params, 8, 24, seed=5))
        rng = np.random.default_rng(0)
        chaos = []
        for u, t in base:
            chaos.append((u, t))
            if rng.random() < 0.5:
                chaos.append((u, t))  # at-least-once transport re-delivery
        assert len(chaos) > len(base)
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                  params, 8, trigger=KBuffer(4))
        reports = replay(svc, iter(chaos))
        agg = Counter(int(m.cid) for rep in reports for m in rep.buffer)
        assert agg == Counter(int(u.cid) for u, _ in chaos)
        assert sum(agg.values()) == svc.stats.accepted == len(chaos)

    def test_out_of_order_delivery_no_deadlock(self):
        hp = FedQSHyperParams(buffer_k=5)
        params = make_mlp_spec().init(KEY)
        base = list(synthetic_stream(params, 12, 30, seed=6))
        shuffled = [base[i] for i in np.random.default_rng(1).permutation(
            len(base))]  # timestamps now arrive non-monotonically
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                  params, 12, trigger=KBuffer(5))
        reports = replay(svc, iter(shuffled))
        assert svc.stats.rounds == len(reports) > 0
        assert sum(rep.n_updates for rep in reports) == len(base)
        assert svc.pending == 0


# ---------------------------------------------------------------------------
# (c) crash / restore with half-full buffers
# ---------------------------------------------------------------------------
class TestCheckpointUnderChaos:
    def test_hier_restore_half_full_buffer_bit_exact(self, tmp_path):
        from repro.hier import HierarchicalService, Topology

        hp = FedQSHyperParams(buffer_k=10)
        params = make_mlp_spec().init(KEY)

        def build():
            return HierarchicalService(
                make_algorithm("fedqs-sgd", hp), hp, params, 24,
                Topology.from_spec("hier:4", 24),
                edge_trigger=lambda e: KBuffer(3))

        stream = _chaos_stream(params)
        ref = build()
        for u, t in stream:
            ref.submit(u, now=t)
        a = build()
        for u, t in stream[:24]:
            a.submit(u, now=t)
        assert a.pending > 0, "the crash must land mid-buffer"
        d = str(tmp_path / "ck")
        a.save(d)
        b = build()
        b.restore(d)
        assert b.pending == a.pending  # tier buffers ARE persisted
        for u, t in stream[24:]:
            b.submit(u, now=t)
        assert b.round == ref.round
        assert _leaves_equal(b.global_params, ref.global_params)

    def test_flat_restore_drops_volatile_buffer_but_serves_on(self, tmp_path):
        # the flat service deliberately does NOT persist its ingest buffer
        # (docs/ROBUSTNESS.md): in-flight updates are lost at a crash, but
        # the restored service must keep firing and never double-count
        hp = FedQSHyperParams(buffer_k=6)
        params = make_mlp_spec().init(KEY)
        stream = _chaos_stream(params)
        half = len(stream) // 2
        a = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                params, 24, trigger=KBuffer(6))
        for u, t in stream[:half]:
            a.submit(u, now=t)
        d = str(tmp_path / "ck")
        a.save(d)
        b = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                params, 24, trigger=KBuffer(6))
        b.restore(d)
        assert b.pending == 0  # volatile buffer gone by design
        before_round, before_accepted = b.round, b.stats.accepted
        assert before_accepted == a.stats.accepted
        reports = replay(b, iter(stream[half:]))
        assert b.round > before_round, "restored service must keep firing"
        # exactly the post-restore admissions aggregate — lost buffer rows
        # are not resurrected, new ones are not double-counted
        assert sum(rep.n_updates for rep in reports) == \
            b.stats.accepted - before_accepted


# ---------------------------------------------------------------------------
# (d) edge death between fires: the plane keeps serving, loses exactly the
#     dead edge's buffered rows, and double-counts nothing
# ---------------------------------------------------------------------------
class TestEdgeDeath:
    def test_edge_buffer_wipe_loses_only_buffered_members(self):
        from repro.hier import HierarchicalService, Topology

        hp = FedQSHyperParams(buffer_k=8)
        params = make_mlp_spec().init(KEY)
        reports = []
        svc = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 24,
            Topology.from_spec("hier:4", 24),
            edge_trigger=lambda e: KBuffer(3), on_round=reports.append)
        stream = _chaos_stream(params, seed=11)
        for u, t in stream[:24]:
            svc.submit(u, now=t)
        victim = max(svc.edges, key=lambda e: e.pending)
        lost = victim.pending
        assert lost > 0, "need a victim edge with buffered updates"
        victim.buffer.clear()  # the edge dies; its RAM buffer is gone
        last = 0.0
        for u, t in stream[24:]:
            svc.submit(u, now=t)
            last = t
        svc.flush(now=last)
        assert svc.pending == 0
        total = sum(rep.n_updates for rep in reports)
        assert total == svc.stats.accepted - lost


# ---------------------------------------------------------------------------
# (e) replay determinism: the whole chaos schedule is a function of its seed
# ---------------------------------------------------------------------------
class TestReplayDeterminism:
    def _run(self, seed):
        hp = FedQSHyperParams(buffer_k=8)
        params = make_mlp_spec().init(KEY)
        tel = Telemetry.in_memory()
        stream = list(scenario_stream(params, get_scenario("straggler-heavy"),
                                      64, 200, seed=seed, telemetry=tel))
        svc = StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, 64,
            trigger=AdaptiveTimeWindow(window=2.0, min_updates=2),
            admission=StalenessAdmission(2), batched=True, telemetry=tel)
        replay(svc, iter(stream))
        return svc, tel

    @staticmethod
    def _scrub(records):
        # metrics snapshots fold wall-clock histograms and RoundFired
        # carries host aggregation timing — everything else must replay
        out = []
        for r in records:
            if r.get("e") == "metrics-snapshot":
                continue
            r = dict(r)
            r.pop("agg_seconds", None)
            out.append(r)
        return out

    def test_same_seed_bit_identical(self):
        a, ta = self._run(17)
        b, tb = self._run(17)
        assert _leaves_equal(a.global_params, b.global_params)
        for f in ("submitted", "accepted", "dropped", "downweighted",
                  "partial", "rounds"):
            assert getattr(a.stats, f) == getattr(b.stats, f)
        assert self._scrub(ta.ring.records) == self._scrub(tb.ring.records)

    def test_straggler_run_adapts_deadline(self):
        _, tel = self._run(17)
        kinds = Counter(r["e"] for r in tel.ring.records)
        assert kinds["deadline-adapted"] > 0
        assert kinds["partial-admitted"] > 0

    def test_different_seed_diverges(self):
        a, _ = self._run(17)
        b, _ = self._run(18)
        assert not _leaves_equal(a.global_params, b.global_params)
