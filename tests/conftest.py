import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real (1-device) CPU topology; only
# repro.launch.dryrun uses the 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    settings = None
else:
    settings.register_profile("ci", deadline=None, max_examples=25)
    # the kernel-parity fuzz gate (scripts/ci.sh): derandomized so every
    # run draws the same examples — a red CI is a real regression, never
    # an unlucky draw; sized to keep the interpret-mode sweep ~30 s
    settings.register_profile("kernel-ci", deadline=None, max_examples=20,
                              derandomize=True)
    # the concurrency soak (scripts/ci.sh stress step): derandomized like
    # kernel-ci so a red soak is a real regression, sized up because the
    # stress plane budgets minutes, not seconds
    settings.register_profile("stress", deadline=None, max_examples=50,
                              derandomize=True)
    settings.load_profile("ci")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "stress: long-running concurrency soak — excluded from tier-1, "
        "run explicitly with `-m stress` (scripts/ci.sh)")


def pytest_collection_modifyitems(config, items):
    # tier-1 (`pytest -x -q`, no -m) must stay fast and deterministic:
    # soak tests only run when the stress plane is asked for by name
    if "stress" in (config.getoption("-m") or ""):
        return
    import pytest

    skip = pytest.mark.skip(reason="stress soak: run with -m stress")
    for item in items:
        if "stress" in item.keywords:
            item.add_marker(skip)

collect_ignore: list = []
if settings is None:
    # property-based suites need hypothesis; skip collecting them on a
    # bare environment instead of dying with ModuleNotFoundError
    import pathlib
    import re

    here = pathlib.Path(__file__).parent
    for path in here.glob("test_*.py"):
        if re.search(r"^\s*(from|import) hypothesis", path.read_text(), re.M):
            collect_ignore.append(path.name)
