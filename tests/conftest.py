import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real (1-device) CPU topology; only
# repro.launch.dryrun uses the 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")
