"""§Perf optimization levers must be *numerically equivalent* to their
baseline paths — the speedups in EXPERIMENTS §Perf are only valid if the
optimized programs compute the same function."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _roundtrip(cfg, n_prompt=8, n_decode=3, seed=0):
    """prefill + a few decode steps → stacked logits."""
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1),
                              (2, n_prompt + n_decode), 0, cfg.vocab)
    me = None
    if cfg.frontend != "none":
        me = jax.random.normal(KEY, (2, cfg.n_frontend_tokens, cfg.d_model))
    logits, cache = T.prefill(cfg, params, toks[:, :n_prompt], me,
                              max_seq=n_prompt + n_decode + 2)
    outs = [np.asarray(logits)]
    for i in range(n_decode):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, n_prompt + i], me)
        outs.append(np.asarray(lg))
    return np.stack(outs), params


class TestAbsorbedMLA:
    def test_absorbed_equals_naive_decode(self):
        """mla_absorbed folds W_UK/W_UV algebraically — same function."""
        base = dataclasses.replace(get_reduced("deepseek-v3-671b"),
                                   capacity_factor=8.0)
        opt = dataclasses.replace(base, mla_absorbed=True)
        a, _ = _roundtrip(base)
        b, _ = _roundtrip(opt)
        np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-2)

    def test_absorbed_core_matches_expand_path(self):
        """Direct unit check of the absorbed attention math."""
        from repro.models import layers as L
        cfg = get_reduced("deepseek-v3-671b")
        p = L.mla_init(KEY, cfg.d_model, cfg.n_heads, cfg, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model))
        pos = jnp.asarray([5])
        q, k, v, latent = L.mla_qkv(p, x, cfg.n_heads, cfg, pos, 1e4)
        # build a fake cache of 6 positions ending with this latent
        lat_cache = jnp.concatenate(
            [jax.random.normal(jax.random.PRNGKey(2),
                               (2, 5, latent.shape[-1])) * 0.1, latent], axis=1)
        valid = jnp.asarray(6)
        k_all, v_all = L.mla_expand(p, lat_cache, cfg.n_heads, cfg)
        want = L.decode_attention(q, k_all, v_all, valid)
        q_nope, q_rope, _ = L.mla_q_and_latent(p, x, cfg.n_heads, cfg, pos, 1e4)
        got = L.mla_absorbed_decode(p, q_nope, q_rope, lat_cache, valid,
                                    cfg.n_heads, cfg)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestCrossKVCache:
    @pytest.mark.parametrize("aid", ["seamless-m4t-medium", "llama-3.2-vision-90b"])
    def test_cached_cross_kv_equals_recompute(self, aid):
        base = get_reduced(aid)
        opt = dataclasses.replace(base, cache_cross_kv=True)
        a, _ = _roundtrip(base)
        b, _ = _roundtrip(opt)
        np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-2)


class TestRemat:
    def test_remat_same_loss_and_grads(self):
        cfg = get_reduced("gemma3-1b")
        opt = dataclasses.replace(cfg, remat=True)
        params = T.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "targets": toks}
        f = jax.value_and_grad(lambda p: T.train_loss(cfg, p, batch))
        g = jax.value_and_grad(lambda p: T.train_loss(opt, p, batch))
        la, ga = f(params)
        lb, gb = g(params)
        assert float(la) == pytest.approx(float(lb), rel=1e-5)
        for x, y in zip(jax.tree_util.tree_leaves(ga),
                        jax.tree_util.tree_leaves(gb)):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       rtol=2e-2, atol=2e-3)


class TestTreeVdot:
    def test_sharding_safe_vdot_matches_ravel_vdot(self):
        from repro.core.distributed import _tree_vdot
        tree_a = {"x": jax.random.normal(KEY, (3, 5, 7)),
                  "y": jax.random.normal(jax.random.PRNGKey(1), (11,))}
        tree_b = jax.tree_util.tree_map(lambda t: t * 0.5 + 0.1, tree_a)
        want = sum(float(jnp.vdot(a, b)) for a, b in
                   zip(jax.tree_util.tree_leaves(tree_a),
                       jax.tree_util.tree_leaves(tree_b)))
        got = float(_tree_vdot(tree_a, tree_b))
        assert got == pytest.approx(want, rel=1e-5)
