"""Mod-3 (server aggregation) unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.aggregation import (
    aggregate_gradients,
    aggregate_models,
    aggregation_weights,
    feedback_weight,
    server_aggregate,
    staleness_weight,
    update_table,
)
from repro.core.types import (
    AggregationStrategy,
    FedQSHyperParams,
    ServerTable,
    Update,
    tree_weighted_sum,
)

HP = FedQSHyperParams()


class TestTable:
    def test_eq1_updates(self):
        t = ServerTable.init(4)
        t = update_table(t, jnp.asarray([1, 3]), jnp.asarray([0.5, -0.2]))
        assert t.counts.tolist() == [0, 1, 0, 1]
        np.testing.assert_allclose(np.asarray(t.sims), [0, 0.5, 0, -0.2], atol=1e-7)

    def test_duplicate_cids_count_twice(self):
        t = ServerTable.init(2)
        t = update_table(t, jnp.asarray([0, 0]), jnp.asarray([0.1, 0.7]))
        assert int(t.counts[0]) == 2
        assert float(t.sims[0]) == pytest.approx(0.7)  # last wins


class TestWeights:
    def test_staleness_weight_identity_at_phi(self):
        assert float(staleness_weight(jnp.float32(0.3), jnp.float32(0.3))) == pytest.approx(1.0)

    def test_feedback_weight_formula(self):
        K, N = 10, 100
        F, G = jnp.float32(0.5), jnp.float32(2.0)
        phi = K / N
        want = np.exp(phi - 0.5) / 2 ** (phi - 0.5) * (1 + 2.0) ** 2 / K
        assert float(feedback_weight(F, G, K, N)) == pytest.approx(want, rel=1e-5)

    @given(hnp.arrays(np.float32, st.integers(2, 12),
                      elements=st.floats(0.125, 10.0)))
    def test_weights_normalized_and_nonnegative(self, fg):
        K = len(fg)
        n = jnp.ones((K,), jnp.int32) * 10
        fb = jnp.asarray([i % 2 == 0 for i in range(K)])
        p = aggregation_weights(n, fb, jnp.asarray(fg), jnp.asarray(fg), K, 100)
        p = np.asarray(p)
        assert (p >= 0).all()
        assert p.sum() == pytest.approx(1.0, abs=1e-5)

    def test_no_feedback_gives_sample_weights(self):
        n = jnp.asarray([10, 30], jnp.int32)
        fb = jnp.asarray([False, False])
        p = aggregation_weights(n, fb, jnp.ones(2), jnp.ones(2), 2, 10)
        np.testing.assert_allclose(np.asarray(p), [0.25, 0.75], atol=1e-6)


class TestAggregation:
    def test_gradient_aggregation_descends(self):
        w = {"a": jnp.asarray([1.0, 1.0])}
        deltas = [{"a": jnp.asarray([0.2, 0.0])}, {"a": jnp.asarray([0.0, 0.4])}]
        new = aggregate_gradients(w, deltas, jnp.asarray([0.5, 0.5]), eta_g=1.0)
        np.testing.assert_allclose(np.asarray(new["a"]), [0.9, 0.8], atol=1e-6)

    @given(hnp.arrays(np.float32, (3, 4), elements=st.floats(-5, 5, width=32)))
    def test_model_aggregation_is_convex_combination(self, ws):
        models = [{"w": jnp.asarray(row)} for row in ws]
        p = jnp.asarray([0.2, 0.3, 0.5])
        out = np.asarray(aggregate_models(models, p)["w"])
        lo, hi = ws.min(0), ws.max(0)
        assert (out >= lo - 1e-4).all() and (out <= hi + 1e-4).all()

    def test_tree_weighted_sum_linear(self):
        trees = [{"x": jnp.ones(3) * i} for i in (1.0, 2.0)]
        out = tree_weighted_sum(trees, jnp.asarray([0.5, 0.5]))
        np.testing.assert_allclose(np.asarray(out["x"]), 1.5 * np.ones(3))


def _mk_update(cid, sim, feedback, delta_val, n=10):
    return Update(cid=cid, n_samples=n, stale_round=0, lr=0.1,
                  similarity=sim, feedback=feedback, speed_f=0.01,
                  delta={"w": jnp.ones(2) * delta_val},
                  params={"w": jnp.ones(2) * (1 - delta_val)})


class TestServerAggregate:
    def test_full_pass_gradient(self):
        table = ServerTable.init(10)
        w = {"w": jnp.ones(2)}
        buf = [_mk_update(0, 0.5, False, 0.1), _mk_update(1, 0.3, True, 0.2)]
        new, table2, p = server_aggregate(
            AggregationStrategy.GRADIENT, w, buf, table, HP, 10)
        assert float(jnp.sum(p)) == pytest.approx(1.0, abs=1e-5)
        assert int(table2.counts[0]) == 1 and int(table2.counts[1]) == 1
        # descent happened
        assert (np.asarray(new["w"]) < 1.0).all()

    def test_full_pass_model(self):
        table = ServerTable.init(10)
        w = {"w": jnp.ones(2)}
        buf = [_mk_update(0, 0.5, False, 0.1), _mk_update(1, 0.3, False, 0.2)]
        new, _, p = server_aggregate(
            AggregationStrategy.MODEL, w, buf, table, HP, 10)
        lo = min(0.9, 0.8)
        hi = max(0.9, 0.8)
        assert (np.asarray(new["w"]) >= lo - 1e-6).all()
        assert (np.asarray(new["w"]) <= hi + 1e-6).all()

    def test_feedback_ablation_switch(self):
        hp = FedQSHyperParams(use_feedback=False)
        table = ServerTable.init(10)
        w = {"w": jnp.ones(2)}
        buf = [_mk_update(0, 0.5, True, 0.1), _mk_update(1, 0.3, True, 0.2)]
        _, _, p = server_aggregate(AggregationStrategy.MODEL, w, buf, table, hp, 10)
        np.testing.assert_allclose(np.asarray(p), [0.5, 0.5], atol=1e-6)
