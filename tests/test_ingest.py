"""Fused-ingestion regression gates (docs/KERNELS.md):

* a fused serve round tracks the unfused batched round to ≤1e-5 on the
  global model, for both FedQS strategies, dense and int8 streams, flat
  and hierarchical services;
* round *bookkeeping* — the §3.4 status table — is bit-identical with
  fusion toggled off;
* the fused path stacks the buffer exactly once per fire and reuses the
  flat global between rounds (the ``_flat_cache`` handshake).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import ClientCompressor, compress_stream
from repro.core import FedQSHyperParams, make_algorithm
from repro.core.types import AggregationStrategy
from repro.hier import HierarchicalService, Topology
from repro.models import make_mlp_spec
from repro.serve import KBuffer, StreamingAggregator, replay, synthetic_stream
from repro.serve import batched as serve_batched

KEY = jax.random.PRNGKey(0)
REL_GATE = 1e-5


def _rel_gap(a_tree, b_tree):
    a = jnp.concatenate([l.reshape(-1) for l in jax.tree_util.tree_leaves(a_tree)])
    b = jnp.concatenate([l.reshape(-1) for l in jax.tree_util.tree_leaves(b_tree)])
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b), 1e-12))


def _svc(algo, hp, params, n, *, fused, **kw):
    return StreamingAggregator(make_algorithm(algo, hp), hp, params, n,
                               batched=True, fused=fused, **kw)


def _run_pair(algo, stream, params, n, *, hp=None, **kw):
    hp = hp or FedQSHyperParams(buffer_k=8)
    fused = _svc(algo, hp, params, n, fused=True, **kw)
    plain = _svc(algo, hp, params, n, fused=False, **kw)
    replay(fused, stream, flush=False)
    replay(plain, stream, flush=False)
    return fused, plain


class TestServeFusedParity:
    @pytest.mark.parametrize("algo", ["fedqs-sgd", "fedqs-avg"])
    def test_dense_rounds_match_unfused(self, algo):
        params = make_mlp_spec().init(KEY)
        stream = list(synthetic_stream(params, 16, 48, seed=0))
        fused, plain = _run_pair(algo, stream, params, 16)
        assert fused.round == plain.round >= 6
        gap = _rel_gap(fused.global_params, plain.global_params)
        assert gap <= REL_GATE, f"{algo}: fused/unfused rel gap {gap:.3e}"

    @pytest.mark.parametrize("algo", ["fedqs-sgd", "fedqs-avg"])
    def test_table_bookkeeping_bitexact(self, algo):
        """Fusion must not perturb Eq. 1/2: counts and sims bit-identical
        with the toggle off — the table feeds client selection (Mod-1),
        so even 1-ulp drift would fork the two services' futures."""
        params = make_mlp_spec().init(KEY)
        stream = list(synthetic_stream(params, 16, 48, seed=1))
        fused, plain = _run_pair(algo, stream, params, 16)
        np.testing.assert_array_equal(np.asarray(fused.table.counts),
                                      np.asarray(plain.table.counts))
        np.testing.assert_array_equal(np.asarray(fused.table.sims),
                                      np.asarray(plain.table.sims))

    def test_int8_stream_matches_unfused(self):
        params = make_mlp_spec().init(KEY)
        comp = ClientCompressor("int8", 16, seed=0)
        base = list(synthetic_stream(params, 16, 48, seed=2))
        stream = list(compress_stream(iter(base), comp,
                                      strategy=AggregationStrategy.GRADIENT))
        fused, plain = _run_pair("fedqs-sgd", stream, params, 16)
        assert fused.round == plain.round >= 6
        gap = _rel_gap(fused.global_params, plain.global_params)
        assert gap <= REL_GATE, f"int8 fused/unfused rel gap {gap:.3e}"
        np.testing.assert_array_equal(np.asarray(fused.table.counts),
                                      np.asarray(plain.table.counts))

    def test_interpret_kernel_matches_ref_mode(self):
        """use_kernel=True routes the fused round through the interpret
        Pallas body; it must agree with the jnp ref mode to the gate."""
        params = make_mlp_spec().init(KEY)
        stream = list(synthetic_stream(params, 8, 16, seed=3))
        hp = FedQSHyperParams(buffer_k=8)
        kern = _svc("fedqs-sgd", hp, params, 8, fused=True, use_kernel=True)
        ref = _svc("fedqs-sgd", hp, params, 8, fused=True, use_kernel=False)
        replay(kern, stream, flush=False)
        replay(ref, stream, flush=False)
        gap = _rel_gap(kern.global_params, ref.global_params)
        assert gap <= REL_GATE


class TestFusedMechanics:
    def test_stacks_once_per_fire(self):
        """The fused round makes exactly ONE stacked dispatch per fire —
        the serve_timewindow regression (90 eager dispatches/fire) stays
        fixed.  ``STACK_CALLS`` counts entries into stack_trees/encoded."""
        params = make_mlp_spec().init(KEY)
        stream = list(synthetic_stream(params, 16, 48, seed=4))
        svc = _svc("fedqs-sgd", FedQSHyperParams(buffer_k=8), params, 16,
                   fused=True)
        before = dict(serve_batched.STACK_CALLS)
        replay(svc, stream, flush=False)
        calls = sum(serve_batched.STACK_CALLS.values()) - sum(before.values())
        assert svc.round == 6
        assert calls == svc.round, (
            f"{calls} stack dispatches over {svc.round} rounds — "
            "the fused path must stack each buffer exactly once")

    def test_flat_cache_handshake(self):
        params = make_mlp_spec().init(KEY)
        stream = list(synthetic_stream(params, 8, 16, seed=5))
        svc = _svc("fedqs-sgd", FedQSHyperParams(buffer_k=8), params, 8,
                   fused=True)
        assert svc._flat_cache is None and svc._pending_flat is None
        replay(svc, stream, flush=False)
        assert svc.round == 2
        # after a fire: pending consumed, cache points at the *current*
        # global (identity, not equality — a new params object must miss)
        assert svc._pending_flat is None
        assert svc._flat_cache is not None
        assert svc._flat_src is svc.global_params
        flat, _ = jax.flatten_util.ravel_pytree(svc.global_params)
        np.testing.assert_array_equal(np.asarray(svc._flat_cache),
                                      np.asarray(flat))

    def test_restore_clears_flat_cache(self, tmp_path):
        params = make_mlp_spec().init(KEY)
        stream = list(synthetic_stream(params, 8, 24, seed=6))
        svc = _svc("fedqs-sgd", FedQSHyperParams(buffer_k=8), params, 8,
                   fused=True)
        replay(svc, stream[:16], flush=False)
        path = str(tmp_path / "ckpt")
        svc.save(path)
        replay(svc, stream[16:], flush=False)
        assert svc._flat_cache is not None
        svc.restore(path)
        # the cache must not survive restore: global_params was replaced
        # under it, and a stale flat would silently corrupt every
        # subsequent fused round
        assert svc._flat_cache is None and svc._flat_src is None
        assert svc._pending_flat is None
        # and the service still rounds correctly post-restore
        fresh = _svc("fedqs-sgd", FedQSHyperParams(buffer_k=8), params, 8,
                     fused=True)
        replay(fresh, stream, flush=False)
        replay(svc, stream[16:], flush=False)
        gap = _rel_gap(svc.global_params, fresh.global_params)
        assert gap <= REL_GATE

    def test_fused_toggle_default_follows_batched(self):
        params = make_mlp_spec().init(KEY)
        hp = FedQSHyperParams(buffer_k=4)
        assert StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, 8,
            batched=True)._fused
        assert not StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, 8)._fused


class TestHierFusedParity:
    def _hier(self, params, hp, *, fused):
        return HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 16,
            Topology.from_spec("hier:4", 16),
            edge_trigger=lambda e: KBuffer(2), fused=fused)

    def test_int8_edge_rounds_match_unfused(self):
        """The int8 edge keeps rows quantized up to the fused global
        combine; toggling fusion off (eager dequant + host weights) must
        land within the serve gate."""
        params = make_mlp_spec().init(KEY)
        hp = FedQSHyperParams(buffer_k=8)
        comp = ClientCompressor("int8", 16, seed=0)
        base = list(synthetic_stream(params, 16, 64, seed=7))
        stream = list(compress_stream(iter(base), comp,
                                      strategy=AggregationStrategy.GRADIENT))
        fused = self._hier(params, hp, fused=True)
        plain = self._hier(params, hp, fused=False)
        fused.compressor = comp
        plain.compressor = comp
        replay(fused, stream)
        replay(plain, stream)
        assert fused.round == plain.round >= 4
        gap = _rel_gap(fused.global_params, plain.global_params)
        assert gap <= REL_GATE, f"hier int8 fused/unfused rel gap {gap:.3e}"
        np.testing.assert_array_equal(np.asarray(fused.table.counts),
                                      np.asarray(plain.table.counts))

    def test_dense_rounds_match_unfused(self):
        params = make_mlp_spec().init(KEY)
        hp = FedQSHyperParams(buffer_k=8)
        stream = list(synthetic_stream(params, 16, 64, seed=8))
        fused = self._hier(params, hp, fused=True)
        plain = self._hier(params, hp, fused=False)
        replay(fused, stream)
        replay(plain, stream)
        gap = _rel_gap(fused.global_params, plain.global_params)
        assert gap <= REL_GATE, f"hier dense fused/unfused rel gap {gap:.3e}"
