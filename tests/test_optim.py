"""Eq-3 momentum optimizer + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.types import tree_clip_by_global_norm, tree_global_norm
from repro.optim import eq3_momentum_step, local_train_epochs, wsd_schedule


def test_eq3_recursion_matches_closed_form():
    """v_e = g_e + m·g_{e−1} + m²·g_{e−2} + …  (paper Eq. 3 bracket)."""
    m = 0.5
    gs = [jnp.asarray([1.0]), jnp.asarray([2.0]), jnp.asarray([4.0])]
    w = jnp.asarray([0.0])
    v = jnp.zeros(1)
    steps = []
    for g in gs:
        w, v = eq3_momentum_step(w, v, g, lr=1.0, momentum=m)
        steps.append(float(v[0]))
    # closed forms
    assert steps[0] == pytest.approx(1.0)
    assert steps[1] == pytest.approx(2.0 + m * 1.0)
    assert steps[2] == pytest.approx(4.0 + m * 2.0 + m * m * 1.0)


def test_zero_momentum_is_plain_sgd():
    w = jnp.asarray([1.0])
    v = jnp.zeros(1)
    w2, _ = eq3_momentum_step(w, v, jnp.asarray([0.5]), lr=0.1, momentum=0.0)
    assert float(w2[0]) == pytest.approx(1.0 - 0.05)


def test_local_train_delta_equals_eta_sum_v():
    """Uploaded δ = w_start − w_end = η Σ_e v_e (Remark B.1)."""
    grads = iter([{"w": jnp.asarray([1.0])}, {"w": jnp.asarray([1.0])}])

    def grad_fn(params, batch):
        return next(grads)

    w0 = {"w": jnp.asarray([0.0])}
    w_end, _ = local_train_epochs(w0, grad_fn, [None, None], lr=0.1,
                                  momentum=0.5, grad_clip=100.0)
    # v1=1, v2=1+0.5=1.5 ⇒ δ=0.1·2.5=0.25
    assert float(w0["w"][0] - w_end["w"][0]) == pytest.approx(0.25)


@given(st.floats(0.1, 50.0))
def test_clip_by_global_norm_bound(max_norm):
    tree = {"a": jnp.ones((4,)) * 10.0, "b": jnp.ones((2, 2)) * -10.0}
    clipped = tree_clip_by_global_norm(tree, max_norm)
    assert float(tree_global_norm(clipped)) <= max_norm * (1 + 1e-5)


def test_clip_noop_under_threshold():
    tree = {"a": jnp.asarray([0.1, 0.1])}
    out = tree_clip_by_global_norm(tree, 20.0)
    np.testing.assert_allclose(np.asarray(out["a"]), [0.1, 0.1], rtol=1e-6)


def test_wsd_schedule_phases():
    sched = wsd_schedule(1.0, warmup_steps=10, stable_steps=10, decay_steps=10)
    assert float(sched(0)) == pytest.approx(0.0)
    assert float(sched(5)) == pytest.approx(0.5)
    assert float(sched(15)) == pytest.approx(1.0)
    assert float(sched(30)) == pytest.approx(0.1, abs=1e-6)  # final_ratio
    # monotone decay in the tail
    assert float(sched(22)) > float(sched(27))
